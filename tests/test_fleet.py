"""Fleet-scale test wall: cohort bit-identity, batched control plane,
sharded clock + bridged multi-broker fabric, and the ``-m fleet`` matrix
(churn / partition / straggler / dup-storm at 5k logical clients, 2k-node
placement, timer-drain regression).

The unmarked tests are tier-1 (fast, exact); the ``fleet``-marked ones run
thousands of logical clients and live in their own CI job.
"""
import time
import tracemalloc

import numpy as np
import pytest

from repro.api import Federation, LatencyTransport, SimClock
from repro.api.fleet import build_fabric
from repro.core.broker import SimBroker
from repro.core.cohort import CohortClient, ParamBank
from repro.core.clustering import build_tree, validate_tree
from repro.core.role_optimizer import get_policy
from repro.core.stats import StatsSimulator

fleet = pytest.mark.fleet

INIT = {"w": np.arange(8, dtype=np.float32),
        "b": np.ones((2, 3), np.float32)}


def train(cid, start, rnd):
    """Deterministic per-(member, round) local update with distinct values
    per member — any aggregation mistake shows up in the global."""
    v = (int(cid.lstrip("c"), 10) % 97) + 1.0 + 0.1 * rnd
    out = {k: (np.asarray(a, np.float64) * 0.5 + v).astype(np.float32)
           for k, a in start.items()}
    return out, (int(cid.lstrip("c"), 10) % 7) + 1


def run_individual(n, strategy="fedavg", rounds=2):
    fed = Federation()
    clients = [fed.client(f"c{i:05d}") for i in range(n)]
    session = fed.create_session("s", "m", rounds=rounds,
                                 participants=clients, strategy=strategy)
    return session.run(train, initial_params=INIT)


def make_fleet(n, n_cohorts=1, strategy="fedavg", rounds=2, initial=None,
               **fed_kwargs):
    fed = Federation(**fed_kwargs)
    ids = [f"c{i:05d}" for i in range(n)]
    size = -(-n // n_cohorts)
    cohorts = [fed.cohort(f"co{k}", ids[i:i + size])
               for k, i in enumerate(range(0, n, size))]
    session = fed.create_fleet_session("s", "m", rounds=rounds,
                                       cohorts=cohorts, strategy=strategy,
                                       initial_params=initial)
    return fed, cohorts, session


# ---------------------------------------------------------------------------
# ParamBank
# ---------------------------------------------------------------------------

class TestParamBank:
    def test_rows_are_views(self):
        bank = ParamBank(["b", "a"], INIT)
        assert bank.ids == ["a", "b"]           # sorted member order
        row = bank.row("a")
        row["w"][0] = 42.0                       # zero-copy: mutates the bank
        assert bank.data["w"][0, 0] == 42.0
        assert bank.data["w"].flags["C_CONTIGUOUS"]

    def test_set_row_and_weight(self):
        bank = ParamBank(["a", "b"], INIT)
        bank.set_row("b", {k: v + 1 for k, v in INIT.items()}, weight=3.0)
        np.testing.assert_array_equal(bank.row("b")["w"], INIT["w"] + 1)
        np.testing.assert_array_equal(bank.row("a")["w"], INIT["w"])
        assert bank.weight("b") == 3.0 and bank.weight("a") == 1.0

    def test_broadcast_and_nbytes(self):
        bank = ParamBank([f"m{i}" for i in range(10)], INIT)
        g = {k: v * 2 for k, v in INIT.items()}
        bank.broadcast(g)
        for i in range(10):
            np.testing.assert_array_equal(bank.data["b"][i], g["b"])
        # struct-of-arrays: memory is N x template + N weights, no overhead
        per = sum(np.asarray(v).nbytes for v in INIT.values())
        assert bank.nbytes == 10 * per + bank.weights.nbytes


# ---------------------------------------------------------------------------
# Bit-identity: one cohort replays N individual clients exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["fedavg", "fedprox", "trimmed_mean"])
@pytest.mark.parametrize("n", [1, 7, 64])
def test_single_cohort_bit_identical_to_individuals(strategy, n):
    ga = run_individual(n, strategy)
    fed, (co,), session = make_fleet(n, 1, strategy)
    gb = session.run(train, initial_params=INIT)
    assert len(ga) == len(gb) == 2
    for r, (a, b) in enumerate(zip(ga, gb)):
        for k in a:
            assert a[k].dtype == b[k].dtype
            np.testing.assert_array_equal(
                a[k], b[k],
                err_msg=f"{strategy} n={n} round {r} key {k} not bit-equal")
    assert co.bypassed_messages > 0          # the fast path actually ran
    assert co.uplink_partials == 0           # one cohort: no remote heads


def test_multi_cohort_matches_to_tolerance():
    """Cross-cohort covers=k partials change the f64 association order, so
    several cohorts agree to float tolerance (not bitwise) — and the
    batched uplink path must actually be exercised."""
    ga = run_individual(24)
    fed, cohorts, session = make_fleet(24, 3)
    gb = session.run(train, initial_params=INIT)
    for a, b in zip(ga, gb):
        for k in a:
            np.testing.assert_allclose(a[k], b[k], rtol=1e-6, atol=1e-6)
    assert sum(co.uplink_partials for co in cohorts) > 0


def test_vectorized_round_equals_member_loop():
    """``run_round_vectorized`` (one call per cohort over the whole bank)
    lands the same global as per-member ``train_members`` with the
    equivalent scalar function."""
    def vtrain(data, weights, g):
        for k in data:
            d = np.arange(data[k].shape[0], dtype=np.float64)
            data[k] = (data[k] * 0.5
                       + d.reshape((-1,) + (1,) * (data[k].ndim - 1))
                       ).astype(np.float32)
        return data, weights

    def strain(cid, start, rnd):
        # member index == bank row index (ids are sorted on creation)
        i = int(cid.lstrip("c"), 10)
        return ({k: (np.asarray(v, np.float64) * 0.5 + i).astype(np.float32)
                 for k, v in start.items()}, 1)

    fed_a, _, sess_a = make_fleet(9, 1, initial=INIT)
    sess_a.run_round(strain)
    fed_b, _, sess_b = make_fleet(9, 1, initial=INIT)
    sess_b.run_round_vectorized(vtrain)
    fed_b.deliver()
    for k in INIT:
        np.testing.assert_array_equal(sess_a.global_params()[k],
                                      sess_b.global_params()[k])


def test_compiled_cohort_step_matches_per_client_loop():
    """The vmapped host-path data plane: ONE ``build_cohort_local_step``
    call over the member-stacked state matches running the n=1 builder on
    each member's slice (the compiled analogue of N individual clients)."""
    jax = pytest.importorskip("jax")
    from repro.configs.base import ShapeConfig, get_arch, smoke_config
    from repro.core.fl_step import build_cohort_local_step, init_cohort_state
    from repro.models import inputs as minputs

    tmap = jax.tree_util.tree_map
    cfg = smoke_config(get_arch("hymba-1.5b"))
    n = 4
    key = jax.random.PRNGKey(0)
    state = init_cohort_state(cfg, n, key)
    batch = minputs.make_batch(cfg, ShapeConfig("t", 16, 8, "train"), key,
                               clients=n)
    new_state, metrics = build_cohort_local_step(cfg, n)(state, batch)
    assert np.isfinite(float(metrics["loss"]))

    step1 = build_cohort_local_step(cfg, 1)
    for i in range(n):
        s_i = {"params": tmap(lambda a: a[i], state["params"]),
               "opt": tmap(lambda a: a[i], state["opt"]),
               "step": state["step"]}
        out_i, _ = step1(s_i, tmap(lambda a: a[i], batch))
        for got, want in zip(jax.tree_util.tree_leaves(new_state["params"]),
                             jax.tree_util.tree_leaves(out_i["params"])):
            np.testing.assert_allclose(
                np.asarray(got[i], np.float32), np.asarray(want, np.float32),
                rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Batched control plane
# ---------------------------------------------------------------------------

class TestCohortControlPlane:
    def test_one_join_rpc_for_all_members(self):
        fed = Federation()
        co = fed.cohort("co0", [f"c{i:05d}" for i in range(50)])
        before = fed.transport.inner.sys_stats()["messages_received"]
        session = fed.create_fleet_session("s", "m", rounds=1, cohorts=[co])
        after = fed.transport.inner.sys_stats()["messages_received"]
        assert session.state == "running"
        assert sorted(co.joined["s"]) == sorted(co.active)
        assert len(session.contributors()) == 50
        # join + topology + batched assignments + global subscriptions:
        # O(1) broker messages, not O(members)
        assert after - before < 25, after - before

    def test_drop_members_shrinks_session(self):
        fed, (co,), session = make_fleet(12, 1, rounds=3, initial=INIT)
        session.run_round(train)
        gone = sorted(co.active)[:5]
        session.drop_members("co0", gone)
        assert len(session.contributors()) == 7
        assert session.member_count() == 7
        g = session.run_round(train)
        assert g is not None and session.global_version() == 2

    def test_cohort_rejects_individual_training_surface(self):
        fed, (co,), session = make_fleet(3, 1)
        with pytest.raises(RuntimeError):
            co.send_local("s")

    def test_cohort_rejects_async_sessions(self):
        fed = Federation()
        co = fed.cohort("co0", ["c0", "c1"])
        ctx = co.models.ensure("sx", "m")
        ctx.async_cfg = {"k": 1}
        co.banks["sx"] = ParamBank(sorted(co.active), INIT)
        with pytest.raises(RuntimeError):
            co.run_local_round("sx")


# ---------------------------------------------------------------------------
# Sharded clock
# ---------------------------------------------------------------------------

class TestShardedClock:
    def test_cross_shard_global_order(self):
        c, out = SimClock(), []
        c.schedule(2.0, lambda: out.append("a2"), shard="a")
        c.schedule(1.0, lambda: out.append("b1"), shard="b")
        c.schedule(1.5, lambda: out.append("c15"), shard="c")
        c.schedule(0.5, lambda: out.append("a05"), shard="a")
        c.run_until_idle()
        assert out == ["a05", "b1", "c15", "a2"]

    def test_same_time_fifo_across_shards(self):
        c, out = SimClock(), []
        for i, shard in enumerate(["a", "b", "a", None, "b"]):
            c.schedule(1.0, lambda i=i: out.append(i), shard=shard)
        c.run_until_idle()
        assert out == [0, 1, 2, 3, 4]

    def test_shards_introspection(self):
        c = SimClock()
        c.schedule(1.0, lambda: None, shard="site0")
        c.schedule(1.0, lambda: None, shard="site0")
        c.schedule(1.0, lambda: None)
        assert c.shards() == {None: 1, "site0": 2}
        assert c.pending(timers=False) == 3

    def test_timer_drain_cost_flat_in_pending_timers(self):
        """Satellite regression: a message-only drain must not touch the
        timer heap.  The old single-heap clock popped and re-pushed every
        earlier timer per delivery — O(timers log n) per message, ~50x
        with 10k armed timers.  The split heaps keep the ratio ~1."""
        def drain_cost(timers, n_msgs=3000):
            clock = SimClock()
            for i in range(timers):
                clock.schedule_periodic(10_000.0 + i, lambda: True)
            lt = LatencyTransport(SimBroker(), delay_s=0.001, clock=clock)
            sink = [0]
            lt.connect("rx", lambda m: sink.__setitem__(0, sink[0] + 1))
            lt.subscribe("rx", "t/#")
            with clock.hold():
                for i in range(n_msgs):
                    lt.publish("t/a", b"x", sender=f"s{i % 16}")
                t0 = time.perf_counter()
                clock.run_until_idle()
                dt = time.perf_counter() - t0
            assert sink[0] == n_msgs
            assert clock.pending(timers=True) == timers  # still armed
            return dt / n_msgs

        drain_cost(0)                                    # warmup
        cold = min(drain_cost(0) for _ in range(3))
        hot = min(drain_cost(10_000) for _ in range(3))
        assert hot / cold < 5.0, (hot, cold)


# ---------------------------------------------------------------------------
# Bridged multi-broker fabric
# ---------------------------------------------------------------------------

class TestBridgedFabric:
    def _mesh(self, n):
        """Hub-and-spoke: site brokers bridged to one core."""
        core = SimBroker("core")
        sites = [SimBroker(f"s{i}") for i in range(n)]
        for s in sites:
            core.bridge(s)
        return core, sites

    def test_hub_and_spoke_no_duplicates(self):
        core, (s0, s1, s2) = self._mesh(3)
        got = []
        s2.connect("rx", lambda m: got.append(m.payload))
        s2.subscribe("rx", "t/#")
        s0.publish("t/x", b"one")            # s0 -> core -> {s1, s2}
        assert got == [b"one"]               # exactly once, two hops

    def test_chain_forwarding(self):
        a, b, c = SimBroker("a"), SimBroker("b"), SimBroker("c")
        a.bridge(b)
        b.bridge(c)
        got = []
        c.connect("rx", lambda m: got.append(m.payload))
        c.subscribe("rx", "t/#")
        a.publish("t/x", b"far")
        assert got == [b"far"]

    def test_bridge_partition_holds_and_replays_in_order(self):
        a, b = SimBroker("a"), SimBroker("b")
        a.bridge(b)
        got = []
        b.connect("rx", lambda m: got.append((m.payload, m.qos)))
        b.subscribe("rx", "t/#", qos=1)
        a.set_bridge_down("b")
        a.publish("t/1", b"q1-first", qos=1)
        a.publish("t/2", b"q0-lost", qos=0)      # dropped: real outage
        a.publish("t/3", b"q1-second", qos=1)
        assert got == []
        a.set_bridge_down("b", down=False)
        assert got == [(b"q1-first", 1), (b"q1-second", 1)]

    def test_fabric_session_matches_single_broker(self):
        ga = run_individual(12)
        fab = build_fabric(n_sites=2)
        ids = [f"c{i:05d}" for i in range(12)]
        cohorts = [fab.cohort("site0", "co0", ids[:6]),
                   fab.cohort("site1", "co1", ids[6:])]
        session = fab.create_fleet_session("s", "m", rounds=2,
                                           cohorts=cohorts)
        gb = session.run(train, initial_params=INIT)
        assert len(gb) == 2
        for a, b in zip(ga, gb):
            for k in a:
                np.testing.assert_allclose(a[k], b[k], rtol=1e-6, atol=1e-6)

    def test_partition_site_stalls_round_heal_completes(self):
        fab = build_fabric(n_sites=2)
        ids = [f"c{i:05d}" for i in range(8)]
        cohorts = [fab.cohort("site0", "co0", ids[:4]),
                   fab.cohort("site1", "co1", ids[4:])]
        session = fab.create_fleet_session("s", "m", rounds=2,
                                           cohorts=cohorts,
                                           initial_params=INIT)
        session.run_round(train)
        assert session.global_version() == 1
        fab.partition_site("site1")
        session.run_round(train)
        assert session.global_version() == 1     # stalled on site1's uplink
        fab.heal_site("site1")                   # backlog replays in order
        assert session.global_version() == 2


# ---------------------------------------------------------------------------
# Fleet matrix (-m fleet): 5k logical clients under adverse conditions
# ---------------------------------------------------------------------------

N_FLEET = 5000
MEM_GATE_KB_PER_1K = 12_000      # measured ~5.7MB/1k; x2 headroom


def _vtrain(data, weights, g):
    for arr in data.values():
        d = (np.arange(arr.shape[0], dtype=np.float64) % 101) / 101.0
        np.multiply(arr, 0.9, out=arr)
        arr += d.reshape((-1,) + (1,) * (arr.ndim - 1))
    return data, weights


def _run_matrix(inject=None, n=N_FLEET, rounds=3):
    """One fleet run, ``inject(fed, cohorts, session, round_idx)`` fired
    before each round.  Returns (final_global, session, peak_bytes)."""
    tracemalloc.start()
    fed, cohorts, session = make_fleet(n, 2, rounds=rounds,
                                       initial={"w": np.zeros(32, np.float32)})
    for r in range(rounds):
        if inject:
            inject(fed, cohorts, session, r)
        session.run_round_vectorized(_vtrain)
        fed.deliver()
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    g = session.global_params()
    assert g is not None
    return g, session, peak


def _assert_gates(session, peak, n=N_FLEET, rounds=3):
    assert session.global_version() == rounds
    assert peak / 1024 / (n / 1000) < MEM_GATE_KB_PER_1K, peak


@fleet
class TestFleetMatrix:
    def test_clean_run_deterministic_across_reruns(self):
        g1, s1, peak = _run_matrix()
        g2, s2, _ = _run_matrix()
        _assert_gates(s1, peak)
        assert g1["w"].tobytes() == g2["w"].tobytes()

    def test_churn_5k(self):
        def inject(fed, cohorts, session, r):
            if r == 1:       # 10% of one cohort leaves between rounds
                session.drop_members(
                    cohorts[0].client_id,
                    sorted(cohorts[0].active)[:N_FLEET // 20])
        g1, s1, peak = _run_matrix(inject)
        _assert_gates(s1, peak)
        assert s1.member_count() == N_FLEET - N_FLEET // 20
        g2, s2, _ = _run_matrix(inject)
        assert g1["w"].tobytes() == g2["w"].tobytes()

    def test_partition_heal_5k(self):
        fed, cohorts, session = make_fleet(
            N_FLEET, 2, rounds=3, initial={"w": np.zeros(32, np.float32)})
        session.run_round_vectorized(_vtrain)
        fed.deliver()
        assert session.global_version() == 1
        other = [co.client_id for co in cohorts[1:]]
        fed.transport.partition([cohorts[0].client_id],
                                other + ["coordinator", "param_server"])
        session.run_round_vectorized(_vtrain)
        fed.deliver()
        assert session.global_version() == 1     # stalled on the cut
        fed.transport.heal()
        assert session.global_version() == 2     # held uplinks replayed

    def test_straggler_5k(self):
        fed, cohorts, session = make_fleet(
            N_FLEET, 2, rounds=3, initial={"w": np.zeros(32, np.float32)})
        fed.transport.set_link(cohorts[0].client_id, delay_s=5.0)
        for _ in range(3):
            session.run_round_vectorized(_vtrain)
            fed.deliver()
        assert session.global_version() == 3
        assert fed.clock.now >= 5.0              # waited for the straggler

    def test_dup_storm_5k(self):
        """QoS 1 duplicate storm on one cohort's uplink: receiver-side
        dedup keeps the global identical to the clean run."""
        g_clean, _, _ = _run_matrix()

        def inject(fed, cohorts, session, r):
            if r == 0:
                fed.transport.set_link(cohorts[0].client_id, dup_p=0.7)
        g_dup, s, peak = _run_matrix(inject)
        _assert_gates(s, peak)
        assert g_clean["w"].tobytes() == g_dup["w"].tobytes()


@fleet
@pytest.mark.parametrize("policy", ["round_robin", "genetic",
                                    "reputation_aware"])
def test_placement_2k_terminates_fast_with_valid_heads(policy):
    n = 2000
    ids = [f"c{i:05d}" for i in range(n)]
    sim = StatsSimulator(ids)
    stats = {c: sim.sample(c, 0) for c in ids}
    t0 = time.perf_counter()
    ranked = get_policy(policy)(stats, round_idx=3)
    tree = build_tree("s", ids, ranked, aggregator_ratio=0.3, levels=3)
    dt = time.perf_counter() - t0
    assert dt < 5.0, f"{policy} took {dt:.1f}s"
    assert sorted(ranked) == ids                 # a permutation: no dupes
    assert validate_tree(tree, ids) == []
    heads = {c.head for c in tree.all_clusters()}
    assert heads <= set(ids)
