"""Substrate tests: optimizers, checkpointing, data pipeline, FT policies,
losses, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ckpt.manager import CheckpointManager
from repro.data.federated import FederatedTokens, dirichlet_split, iid_split
from repro.data.synthetic import TokenStream, mnist_like
from repro.dist.compression import (dequantize_int8, quantize_int8,
                                    quantize_with_error_feedback)
from repro.ft.failures import FailurePlan, StragglerPolicy, demote_stragglers
from repro.models.model_api import cross_entropy
from repro.optim.api import (adafactor, adamw, apply_updates, constant,
                             make_optimizer, sgdm, warmup_cosine)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda: sgdm(constant(0.1)),
    lambda: adamw(constant(0.05)),
    lambda: adafactor(constant(0.5)),
])
def test_optimizer_minimizes_quadratic(make):
    opt = make()
    params = {"x": jnp.array([3.0, -2.0]), "W": jnp.ones((4, 3))}
    target = {"x": jnp.array([1.0, 1.0]), "W": jnp.zeros((4, 3))}
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2)
                   for a, b in zip(jax.tree_util.tree_leaves(p),
                                   jax.tree_util.tree_leaves(target)))

    l0 = loss(params)
    for step in range(60):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params, jnp.int32(step))
        params = apply_updates(params, upd)
    assert loss(params) < 0.2 * l0


def test_adafactor_state_is_factored():
    opt = adafactor()
    p = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    s = opt.init(p)
    assert s["f"]["w"]["vr"].shape == (64,)
    assert s["f"]["w"]["vc"].shape == (32,)
    assert s["f"]["b"]["v"].shape == (64,)


def test_warmup_cosine_schedule_shape():
    lr = warmup_cosine(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0, abs=1e-3)
    assert float(lr(100)) < float(lr(50)) < float(lr(10))


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def test_vocab_parallel_ce_matches_naive():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 5, 17))
    labels = jax.random.randint(key, (2, 5), 0, 17)
    got = cross_entropy(logits, labels)
    lp = jax.nn.log_softmax(logits, -1)
    want = -jnp.mean(jnp.take_along_axis(lp, labels[..., None], -1))
    np.testing.assert_allclose(got, want, rtol=1e-5)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_exact(tmp_path):
    state = {
        "params": {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
                   "b": jnp.ones((5,), jnp.float32)},
        "opt": {"m": jnp.full((3, 4), 0.25)},
        "step": jnp.int32(7),
    }
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2)
    mgr.save(7, state, {"loss": 1.5})
    back, meta = mgr.restore_latest(like=state)
    assert meta["step"] == 7 and meta["loss"] == 1.5
    for a, b in zip(jax.tree_util.tree_leaves(back),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_n_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2)
    st_ = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.full(3, float(s))})
    assert mgr.latest_step() == 4
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path / "ck"))
    assert steps == [3, 4]
    back, _ = mgr.restore_latest(like=st_)
    np.testing.assert_allclose(np.asarray(back["x"]), 4.0)


def test_checkpoint_shape_mismatch_fails(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"))
    mgr.save(1, {"x": jnp.zeros((3,))})
    with pytest.raises(AssertionError):
        mgr.restore_latest(like={"x": jnp.zeros((4,))})


# ---------------------------------------------------------------------------
# Data
# ---------------------------------------------------------------------------

def test_token_stream_is_deterministic_and_learnable():
    s1 = TokenStream(100, seed=1)
    s2 = TokenStream(100, seed=1)
    b1 = s1.batch(4, 32, step=3)
    b2 = s2.batch(4, 32, step=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(
        b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_dirichlet_split_partitions():
    _, y = mnist_like(2000, seed=0)
    parts = dirichlet_split(y, 8, alpha=0.3, seed=0)
    allidx = np.concatenate(parts)
    assert len(allidx) >= 0.99 * 2000     # tiny loss from min-1 fixup ok
    assert all(len(p) >= 1 for p in parts)
    # skew: client class histograms differ
    h0 = np.bincount(y[parts[0]], minlength=10)
    h1 = np.bincount(y[parts[1]], minlength=10)
    assert not np.array_equal(h0, h1)


def test_iid_split_covers_all():
    parts = iid_split(100, 7)
    assert sorted(np.concatenate(parts).tolist()) == list(range(100))


def test_federated_tokens_heterogeneous():
    f = FederatedTokens(vocab=64, n_clients=3, seed=0)
    g = f.global_batch(3, 2, 16, step=0)
    assert g["tokens"].shape == (3, 2, 16)
    assert not np.array_equal(g["tokens"][0], g["tokens"][1])


# ---------------------------------------------------------------------------
# FT policies
# ---------------------------------------------------------------------------

def test_straggler_policy_cuts_after_deadline():
    p = StragglerPolicy(deadline_s=1.0, min_fraction=0.5)
    assert not p.should_cut(0.5, got=3, expected=6)
    assert p.should_cut(1.5, got=3, expected=6)
    assert not p.should_cut(9.9, got=2, expected=6)   # below min fraction
    assert p.should_cut(0.0, got=6, expected=6)


def test_straggler_policy_quantile_deadline():
    p = StragglerPolicy(quantile=0.5)
    for l in [1.0] * 10:
        p.observe(l)
    assert p.deadline() == pytest.approx(1.5)


def test_demote_stragglers_reorders():
    ranked = ["a", "b", "c", "d"]
    lat = {"a": 10.0, "b": 1.0, "c": 1.0, "d": 1.1}
    out = demote_stragglers(lat, ranked)
    assert out.index("a") == len(out) - 1


def test_failure_plan_random_is_deterministic():
    ids = [f"c{i}" for i in range(10)]
    p1 = FailurePlan.random(ids, 20, seed=3)
    p2 = FailurePlan.random(ids, 20, seed=3)
    assert p1.fail_at == p2.fail_at


# ---------------------------------------------------------------------------
# Compression
# ---------------------------------------------------------------------------

def test_int8_quant_bounds():
    x = jax.random.normal(jax.random.PRNGKey(0), (33, 130)) * 7
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    rowmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    assert float(jnp.max(jnp.abs(back - x) / jnp.maximum(rowmax, 1e-9))) \
        <= 1 / 127 + 1e-6


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 50), rounds=st.integers(1, 6))
def test_error_feedback_bounded(seed, rounds):
    """EF keeps the residual bounded (no drift explosion)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,))
    err = jnp.zeros_like(x)
    for _ in range(rounds):
        q, s, err = quantize_with_error_feedback(x, err)
    amax = float(jnp.max(jnp.abs(x + err)))
    assert float(jnp.max(jnp.abs(err))) <= amax / 127 + 1e-6


# ---------------------------------------------------------------------------
# Role optimization policies
# ---------------------------------------------------------------------------

def test_role_policies_return_valid_rankings():
    from repro.core.role_optimizer import get_policy, list_policies
    from repro.core.stats import StatsSimulator
    sim = StatsSimulator([f"c{i}" for i in range(9)])
    stats = {c: sim.sample(c, 2) for c in sim.base}
    for name in list_policies():
        ranked = get_policy(name)(stats, 2)
        assert sorted(ranked) == sorted(stats), name


def test_genetic_policy_prefers_capable_heads():
    """GA should not put the slowest-bandwidth client at the front."""
    from repro.core.role_optimizer import get_policy
    from repro.core.stats import ClientStats
    stats = {f"c{i}": ClientStats(f"c{i}", bandwidth_mbps=1000.0,
                                  cpu_speed=1.0) for i in range(9)}
    stats["c4"] = ClientStats("c4", bandwidth_mbps=0.5, cpu_speed=1.0)
    ranked = get_policy("genetic")(stats, 0)
    n_agg = max(1, round(len(stats) * 0.3))
    assert "c4" not in ranked[:n_agg]
    # deterministic
    assert ranked == get_policy("genetic")(stats, 0)


def test_all_policies_survive_empty_cohort():
    """Total churn mid-round hands the optimizer an empty stats dict; every
    policy must rank nothing as [] (round_robin used to ZeroDivisionError
    on the modulo)."""
    from repro.core.role_optimizer import get_policy, list_policies
    for name in list_policies():
        assert get_policy(name)({}, 3) == [], name


def test_genetic_policy_beats_random_placement():
    """The GA's fitness must actually price the modeled round: across a
    heterogeneous fleet its chosen heads should model a faster round than
    the average random permutation."""
    import numpy as np
    from repro.core.role_optimizer import get_policy
    from repro.core.stats import ClientStats

    rng = np.random.default_rng(5)
    stats = {f"c{i}": ClientStats(f"c{i}",
                                  bandwidth_mbps=float(rng.uniform(1, 200)),
                                  cpu_speed=float(rng.uniform(0.2, 4.0)),
                                  rounds_as_aggregator=int(rng.integers(0, 5)))
             for i in range(12)}
    ids = sorted(stats)
    n_agg = max(1, round(len(ids) * 0.3))

    def modeled_round_s(order):
        heads = order[:n_agg]
        rest = order[n_agg:]
        share = -(-len(rest) // n_agg)
        worst = 0.0
        for hi, h in enumerate(heads):
            members = rest[hi * share:(hi + 1) * share]
            recv = (len(members) + 1) / (stats[h].bandwidth_mbps + 1e-3)
            arrive = max([1.0 / max(stats[m].cpu_speed, 1e-3)
                          for m in members] or [0.0])
            worst = max(worst, max(recv, arrive))
        root_bw = max(stats[h].bandwidth_mbps for h in heads) + 1e-3
        return worst + (n_agg - 1) / root_bw

    ga = modeled_round_s(get_policy("genetic")(stats, 0))
    randoms = []
    for _ in range(200):
        perm = list(rng.permutation(ids))
        randoms.append(modeled_round_s(perm))
    assert ga < np.mean(randoms), (ga, np.mean(randoms))


def test_genetic_policy_single_head_pays_no_fanin():
    """A 3-client fleet has one head; the old fitness charged it
    n_agg/root_bw anyway, skewing rankings toward bandwidth it never
    uses.  With one head the placement should be driven by the members,
    not the head's uplink."""
    from repro.core.role_optimizer import get_policy
    from repro.core.stats import ClientStats
    stats = {
        "c0": ClientStats("c0", bandwidth_mbps=100.0, cpu_speed=3.0),
        "c1": ClientStats("c1", bandwidth_mbps=100.0, cpu_speed=3.0),
        "c2": ClientStats("c2", bandwidth_mbps=100.0, cpu_speed=3.0),
    }
    ranked = get_policy("genetic")(stats, 1)
    assert sorted(ranked) == sorted(stats)
    assert ranked == get_policy("genetic")(stats, 1)    # deterministic
