"""Flash attention (XLA custom_vjp form) vs exact quadratic oracle:
shape/dtype sweeps, SWA, GQA, gradients, decode attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (decode_attention, flash_attention,
                                    full_attention)

CASES = [
    # B, S, H, K, hd, causal, window, dtype
    (2, 128, 4, 2, 16, True, None, jnp.float32),
    (1, 200, 6, 6, 32, True, 64, jnp.float32),
    (2, 96, 4, 1, 8, False, None, jnp.float32),
    (1, 256, 8, 4, 16, True, 32, jnp.bfloat16),
    (3, 64, 2, 2, 24, True, None, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,K,hd,causal,window,dtype", CASES)
def test_flash_matches_full(B, S, H, K, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(B * S), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32).astype(dtype)
    pos = jnp.arange(S)
    o1 = flash_attention(q, k, v, causal, window, 32, 48)
    o2 = full_attention(q, k, v, pos, pos, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=tol, atol=tol)


def test_flash_gradients_match_full():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    B, S, H, K, hd = 2, 160, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    pos = jnp.arange(S)
    t = jnp.sin(jnp.arange(B * S * H * hd).reshape(B, S, H, hd) * 0.01)

    g1 = jax.grad(lambda q, k, v: jnp.sum(
        flash_attention(q, k, v, True, 48, 32, 64) * t), (0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: jnp.sum(
        full_attention(q, k, v, pos, pos, causal=True, window=48) * t),
        (0, 1, 2))(q, k, v)
    for a, b, n in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4,
                                   err_msg=f"d{n}")


def test_decode_matches_full_last_position():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    B, S, H, K, hd = 2, 33, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    pos = jnp.arange(S)
    full = full_attention(q, k, v, pos, pos, causal=True)
    kv_pos = jnp.broadcast_to(pos, (B, S))
    dec = decode_attention(q[:, -1:], k, v, kv_pos,
                           jnp.full((B,), S - 1, jnp.int32))
    np.testing.assert_allclose(dec[:, 0], full[:, -1], rtol=1e-5, atol=1e-6)


def test_decode_window_masks_old_positions():
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    B, S, H, K, hd, W = 1, 40, 2, 2, 8, 8
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    kv_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    pos = jnp.full((B,), S - 1, jnp.int32)
    o_w = decode_attention(q, k, v, kv_pos, pos, window=W)
    # zeroing keys outside the window must not change the output
    keep = (kv_pos[0] > (S - 1 - W))
    k2 = jnp.where(keep[None, :, None, None], k, 100.0)
    v2 = jnp.where(keep[None, :, None, None], v, -100.0)
    o_w2 = decode_attention(q, k2, v2, kv_pos, pos, window=W)
    np.testing.assert_allclose(o_w, o_w2, rtol=1e-5)


def test_empty_slots_are_ignored():
    ks = jax.random.split(jax.random.PRNGKey(12), 3)
    B, S, H, K, hd = 1, 16, 2, 2, 8
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    kv_pos = jnp.where(jnp.arange(S) < 10, jnp.arange(S), -1)
    kv_pos = jnp.broadcast_to(kv_pos, (B, S))
    pos = jnp.full((B,), 9, jnp.int32)
    o = decode_attention(q, k, v, kv_pos, pos)
    o_trunc = decode_attention(q, k[:, :10], v[:, :10], kv_pos[:, :10], pos)
    np.testing.assert_allclose(o, o_trunc, rtol=1e-5)
