"""repro.api tests: the Federation facade (elastic membership, LWT failures,
callbacks), the aggregation-strategy registry (tree == flat equivalence for
every strategy, fedavg bit-identity with the legacy accumulator math), and
the Transport abstraction (protocol conformance, per-link latency/drop)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import (Federation, LatencyTransport, Transport, get_strategy,
                       list_strategies)
from repro.core.broker import Message, SimBroker


def make_session(n, strategy="fedavg", levels=3, ratio=0.4, rounds=3,
                 capacity=None, **fed_kw):
    fed = Federation(aggregator_ratio=ratio, levels=levels, **fed_kw)
    clients = [fed.client(f"c{i}",
                          preferred_role="aggregator" if i % 2 else "trainer")
               for i in range(n)]
    session = fed.create_session("s", "m", rounds=rounds,
                                 participants=clients, strategy=strategy,
                                 capacity=capacity)
    return fed, session


def flat_reference(strategy, params, weights, ref=None):
    """Oracle: the strategy applied to the flat (non-tree) client set."""
    strat = get_strategy(strategy)
    cids = sorted(params)
    if strat.reduction == "stack":
        stacked = {k: np.stack([np.asarray(params[c][k]) for c in cids])
                   for k in params[cids[0]]}
        wv = np.asarray([weights[c] for c in cids], np.float64)
        out = strat.combine(stacked, wv, np)
        return {k: np.asarray(v, np.float32) for k, v in out.items()}
    acc, tw = None, 0.0
    for c in cids:
        contrib = strat.premap(params[c], ref, np)
        w = weights[c]
        if acc is None:
            acc = {k: np.asarray(v, np.float64) * w for k, v in contrib.items()}
        else:
            for k, v in contrib.items():
                acc[k] = acc[k] + np.asarray(v, np.float64) * w
        tw += w
    mean = {k: v / tw for k, v in acc.items()}
    out, _ = strat.finalize(mean, ref, None, np)
    return {k: np.asarray(v, np.float32) for k, v in out.items()}


# ---------------------------------------------------------------------------
# Strategy equivalence: cluster tree == flat reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["fedavg", "fedprox", "trimmed_mean",
                                      "coordinate_median", "fedadam"])
@pytest.mark.parametrize("n,levels,ratio", [(5, 3, 0.4), (9, 3, 0.3),
                                            (16, 4, 0.25)])
def test_strategy_tree_equals_flat(strategy, n, levels, ratio):
    fed, session = make_session(n, strategy, levels, ratio, rounds=1)
    rng = np.random.default_rng(n * 7 + levels)
    params = {f"c{i}": {"w": rng.normal(size=(6, 3)).astype(np.float32),
                        "b": rng.normal(size=(4,)).astype(np.float32)}
              for i in range(n)}
    weights = {f"c{i}": float(rng.integers(1, 9)) for i in range(n)}
    session.run_round(lambda cid, g, r: (params[cid], int(weights[cid])))
    got = session.global_params()
    want = flat_reference(strategy, params, weights)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-6, atol=1e-7)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(3, 14), seed=st.integers(0, 200),
       strategy=st.sampled_from(["trimmed_mean", "coordinate_median"]))
def test_property_robust_strategies_tree_equals_flat(n, seed, strategy):
    """Robust combines are permutation-invariant, so the tree result must be
    bit-identical to the flat stacked reference for any topology."""
    rng = np.random.default_rng(seed)
    levels = int(rng.integers(2, 5))
    ratio = float(rng.uniform(0.2, 0.6))
    fed, session = make_session(n, strategy, levels, ratio, rounds=1)
    params = {f"c{i}": {"w": rng.normal(size=(5,)).astype(np.float32)}
              for i in range(n)}
    weights = {f"c{i}": float(rng.uniform(0.5, 5.0)) for i in range(n)}
    session.run_round(lambda cid, g, r: (params[cid], int(weights[cid]) or 1))
    got = session.global_params()["w"]
    strat = get_strategy(strategy)
    stacked = np.stack([params[f"c{i}"]["w"] for i in range(n)])
    want = strat.combine({"w": stacked}, None, np)["w"]
    np.testing.assert_array_equal(got, np.asarray(want, np.float32))


def test_fedavg_bit_identical_to_legacy_accumulator():
    """The strategy-based path must reproduce the pre-refactor float64
    weighted-sum math bit for bit."""
    n = 7
    fed, session = make_session(n, "fedavg", rounds=1)
    rng = np.random.default_rng(0)
    params = {f"c{i}": {"w": rng.normal(size=(8, 2)).astype(np.float32)}
              for i in range(n)}
    weights = {f"c{i}": float(rng.integers(1, 30)) for i in range(n)}
    session.run_round(lambda cid, g, r: (params[cid], int(weights[cid])))
    got = session.global_params()["w"]
    acc = None
    for c in sorted(params):
        acc = (np.asarray(params[c]["w"], np.float64) * weights[c]
               if acc is None
               else acc + np.asarray(params[c]["w"], np.float64) * weights[c])
    want = (acc / sum(weights.values())).astype(np.float32)
    np.testing.assert_array_equal(got, want)


def test_fedadam_state_rides_global_and_moves_root():
    """Server-optimizer state must survive across rounds even though the
    root aggregator can change (state travels with the retained global)."""
    fed, session = make_session(6, "fedadam", rounds=4)

    def train(cid, g, r):
        # every client reports a constant +1 pseudo-gradient direction
        return {"w": (np.asarray(g["w"]) + 1.0).astype(np.float32)}, 1

    gs = session.run(train, initial_params={"w": np.zeros(4, np.float32)})
    assert len(gs) == 4
    # every participant's ctx carries the replicated server state
    states = [cl.models.get("s").server_state
              for cl in session.participants.values()]
    assert all(s is not None and s["t"] >= 1 for s in states)
    # the server optimizer keeps stepping in the pseudo-gradient direction,
    # using moments accumulated across root changes
    means = [float(np.mean(g["w"])) for g in gs]
    assert means[0] == pytest.approx(1.0)      # round 0: plain mean
    assert means[1] < means[2] < means[3]


def test_fedprox_shrinks_toward_previous_global():
    fed, session = make_session(4, "fedprox", rounds=2)
    rng = np.random.default_rng(1)
    p = {"w": rng.normal(size=(4,)).astype(np.float32)}
    session.run_round(lambda cid, g, r: (p, 1))
    g1 = session.global_params()["w"]          # round 0: no ref -> plain avg
    np.testing.assert_allclose(g1, p["w"], rtol=1e-6)
    q = {"w": (np.asarray(p["w"]) + 1.0).astype(np.float32)}
    session.run_round(lambda cid, g, r: (q, 1))
    g2 = session.global_params()["w"]
    mu = get_strategy("fedprox").mu
    want = (1 - mu) * q["w"] + mu * g1
    np.testing.assert_allclose(g2, want, rtol=1e-5)


def test_tuned_strategy_instance_keeps_hyperparameters():
    """A tuned instance passed to create_session must be what aggregators
    apply — not the registry default re-instantiated by name."""
    from repro.api.strategies import TrimmedMean
    n = 5
    fed = Federation(aggregator_ratio=0.4)
    clients = [fed.client(f"c{i}") for i in range(n)]
    session = fed.create_session("s", "m", rounds=1, participants=clients,
                                 strategy=TrimmedMean(beta=0.4))
    rng = np.random.default_rng(2)
    params = {f"c{i}": {"w": rng.normal(size=(6,)).astype(np.float32)}
              for i in range(n)}
    session.run_round(lambda cid, g, r: (params[cid], 1))
    got = session.global_params()["w"]
    stacked = np.stack([params[f"c{i}"]["w"] for i in range(n)])
    want_04 = TrimmedMean(beta=0.4).combine({"w": stacked}, None, np)["w"]
    want_default = TrimmedMean().combine({"w": stacked}, None, np)["w"]
    np.testing.assert_array_equal(got, np.asarray(want_04, np.float32))
    assert not np.array_equal(got, np.asarray(want_default, np.float32))
    # the tuned instance must not contaminate the shared registry default
    assert get_strategy("trimmed_mean").beta == TrimmedMean().beta


def test_two_sessions_disjoint_clients_both_deliver_callbacks():
    fed = Federation()
    sa = fed.create_session("sa", "m", rounds=1,
                            participants=[fed.client(f"a{i}") for i in range(3)])
    sb = fed.create_session("sb", "m", rounds=1,
                            participants=[fed.client(f"b{i}") for i in range(3)])
    got = []
    sa.on_global_update = lambda p, v: got.append(("sa", v))
    sb.on_global_update = lambda p, v: got.append(("sb", v))
    p = {"w": np.zeros(2, np.float32)}
    sa.run_round(lambda cid, g, r: (p, 1))
    sb.run_round(lambda cid, g, r: (p, 1))
    assert got == [("sa", 1), ("sb", 1)]
    assert sa.global_params() is not None and sb.global_params() is not None


def test_unknown_strategy_fails_fast():
    fed = Federation()
    with pytest.raises(KeyError, match="unknown aggregation strategy"):
        fed.create_session("s", "m", rounds=1,
                           participants=[fed.client("c0")],
                           strategy="does_not_exist")
    assert set(list_strategies()) >= {"fedavg", "fedprox", "trimmed_mean",
                                      "coordinate_median", "fedadam"}


# ---------------------------------------------------------------------------
# Facade: elastic membership, failures, callbacks
# ---------------------------------------------------------------------------

def test_elastic_join_and_leave_through_session():
    fed, session = make_session(4, rounds=4, capacity=(4, 8))
    assert session.state == "waiting"      # headroom left for elastic joins
    assert session.start()                 # waiting time elapsed: quorum ok
    assert session.state == "running"
    p = {"w": np.ones(3, np.float32)}
    session.run_round(lambda cid, g, r: (p, 1))
    late = fed.client("late")
    assert session.join(late)
    assert "late" in session.contributors()
    assert late.arbiter.assignment is not None
    session.run_round(lambda cid, g, r: (p, 1))
    np.testing.assert_allclose(session.global_params()["w"], 1.0)
    session.leave("late")
    assert "late" not in session.contributors()
    session.run_round(lambda cid, g, r: (p, 1))
    assert session.state in ("running", "terminated")


def test_lwt_failure_mid_round_completes_through_session():
    """A client dies abnormally after quorum: the LWT fires, the coordinator
    rearranges, and the round still converges to the live-set average."""
    fed, session = make_session(6, rounds=2)
    params = {f"c{i}": {"w": np.full(3, float(i), np.float32)}
              for i in range(6)}
    session.fail("c5")
    assert "c5" not in session.contributors()
    session.run_round(lambda cid, g, r: (params[cid], 1))
    live = [f"c{i}" for i in range(5)]
    want = np.mean([params[c]["w"] for c in live], axis=0)
    np.testing.assert_allclose(session.global_params()["w"], want, rtol=1e-5)


def test_session_callbacks_fire_once_per_event():
    fed, session = make_session(5, rounds=2)
    updates, rounds = [], []
    session.on_global_update = lambda params, version: updates.append(version)
    session.on_round_start = lambda r: rounds.append(r)
    p = {"w": np.zeros(2, np.float32)}
    session.run(lambda cid, g, r: (p, 1), initial_params=p)
    assert updates == [1, 2]           # deduped across 5 fan-in clients
    # round 0 started inside create_session; assignment replays it
    assert rounds == [0, 1]


def test_run_loop_terminates_at_round_budget():
    fed, session = make_session(3, rounds=3)
    p = {"w": np.zeros(2, np.float32)}
    gs = session.run(lambda cid, g, r: (p, 1))
    assert len(gs) == 3
    assert session.state == "terminated"
    assert session.global_version() == 3


# ---------------------------------------------------------------------------
# Transport
# ---------------------------------------------------------------------------

def test_simbroker_satisfies_transport_protocol():
    assert isinstance(SimBroker(), Transport)
    assert isinstance(LatencyTransport(SimBroker()), Transport)


def test_broker_message_ids_are_per_instance():
    """Two brokers must issue independent mids (QoS-1 dedup isolation) and
    identical runs must produce identical delivery logs."""
    def run():
        b = SimBroker()
        b.log_deliveries = True
        got = []
        b.connect("c", lambda m: got.append(m.mid))
        b.subscribe("c", "t/#", qos=1)
        for i in range(3):
            b.publish("t/x", b"p", qos=1)
        return got, list(b.delivery_log)
    mids1, log1 = run()
    mids2, log2 = run()
    assert mids1 == mids2 == [1, 2, 3]
    assert log1 == log2


def test_latency_transport_drops_qos0_keeps_qos1():
    lt = LatencyTransport(SimBroker(), delay_s=0.01, drop_p=1.0, seed=0)
    got = []
    lt.connect("rx", lambda m: got.append(m.topic))
    lt.subscribe("rx", "t/#", qos=1)
    lt.publish("t/a", b"x", qos=0, sender="tx")
    assert got == []                       # fire-and-forget: lost
    lt.publish("t/b", b"x", qos=1, sender="tx")
    assert got == ["t/b"]                  # at-least-once: retransmitted
    stats = lt.sys_stats()["links"]["tx"]
    assert stats["dropped"] == 1 and stats["retransmits"] == 1


def test_latency_transport_per_link_model_and_virtual_time():
    lt = LatencyTransport(SimBroker(), delay_s=0.01, seed=1)
    lt.set_link("slow", delay_s=0.5)
    lt.connect("rx", lambda m: None)
    lt.subscribe("rx", "t/#")
    for _ in range(10):
        lt.publish("t/x", b"p", sender="fast")
        lt.publish("t/x", b"p", sender="slow")
    s = lt.sys_stats()
    assert s["links"]["slow"]["mean_latency_ms"] > \
        40 * s["links"]["fast"]["mean_latency_ms"]
    assert s["virtual_time_s"] == pytest.approx(10 * 0.51, rel=1e-6)


def test_federation_with_latency_model_still_aggregates_exactly():
    fed, session = make_session(5, rounds=1,
                                latency=dict(delay_s=0.02, jitter_s=0.01,
                                             seed=3))
    p = {"w": np.full(4, 2.0, np.float32)}
    session.run_round(lambda cid, g, r: (p, 1))
    np.testing.assert_allclose(session.global_params()["w"], 2.0)
    assert fed.broker.sys_stats()["virtual_time_s"] > 0
