"""Quickstart over a *real* MQTT broker.

The same Listing-1 scenario as ``examples/quickstart.py``, but every byte
— control plane and model plane — crosses actual MQTT 3.1.1 over TCP
instead of the in-process ``SimBroker``.  By default the script launches
the bundled hermetic mini-broker on an ephemeral port; point ``--broker``
at any MQTT endpoint (e.g. a local ``mosquitto``) to run against real
infrastructure:

    PYTHONPATH=src python examples/real_broker.py
    PYTHONPATH=src python examples/real_broker.py --broker localhost:1883

The script then re-runs the identical workload on ``SimBroker`` and
asserts the final fedavg global is **bit-identical** — the certified
Transport contract plus deterministic per-publish settling means the real
network path is a drop-in swap, not an approximation.
"""
import argparse

import numpy as np

from repro.api import Federation
from repro.api.mini_broker import MiniBroker
from repro.api.mqtt_transport import PahoTransport, paho_available
from repro.data.federated import FederatedMNIST
from repro.train.mlp import accuracy, init_mlp, train_epochs

FL_ROUNDS = 2
N_CLIENTS = 5

parser = argparse.ArgumentParser()
parser.add_argument("--broker", default=None, metavar="HOST:PORT",
                    help="external MQTT broker (default: launch the "
                         "bundled mini-broker)")
parser.add_argument("--backend", default="auto",
                    choices=["auto", "paho", "builtin"],
                    help="MQTT client backend (auto = paho when installed)")
args = parser.parse_args()

data = FederatedMNIST(N_CLIENTS, frac_per_client=0.01, total=10000)
xt, yt = data.test


def train(client_id, global_params, round_idx):
    i = int(client_id.rsplit("_", 1)[1])
    x, y = data.client_data(i)
    local = train_epochs(global_params, x, y, epochs=5, seed=round_idx)
    return local, data.n_samples(i)


def run(transport=None, label="SimBroker"):
    fed = Federation(transport=transport)
    clients = [fed.client(f"client_{i}",
                          preferred_role="aggregator" if i == 0 else "trainer")
               for i in range(N_CLIENTS)]
    session = fed.create_session("session_01", model_name="mlp",
                                 rounds=FL_ROUNDS, participants=clients)
    session.on_global_update = lambda params, version: print(
        f"  [{label}] global v{version}: "
        f"test acc {accuracy(params, xt, yt):.3f}")
    session.run(train, initial_params=init_mlp(seed=0))
    final = session.global_params()
    stats = fed.broker.sys_stats()
    fed.close()
    return final, stats


# --- leg 1: the bundled mini-broker (or an external one) ------------------
mini = None
if args.broker:
    host, _, port = args.broker.rpartition(":")
    transport = PahoTransport(host=host or "127.0.0.1", port=int(port),
                              backend=args.backend)
else:
    mini = MiniBroker(port=0).start()
    print(f"mini-broker listening on 127.0.0.1:{mini.port}")
    transport = PahoTransport(port=mini.port, backend=args.backend)

print(f"MQTT client backend: {transport.backend} "
      f"(paho installed: {paho_available()})")
mqtt_final, mqtt_stats = run(transport, label="MQTT")
print(f"MQTT leg: {mqtt_stats['publishes']} publishes, "
      f"{mqtt_stats['bytes_out']} bytes out, "
      f"{mqtt_stats['barrier_rounds']} flush-barrier rounds")
if mini is not None:
    b = mini.sys_stats()
    print(f"mini-broker routed {b['messages_sent']} messages "
          f"({b['bytes_received']} bytes in)")
    mini.stop()

# --- leg 2: the in-process simulator, same workload -----------------------
sim_final, _ = run(label="sim")

# --- the deployment claim: a drop-in swap, bit for bit --------------------
assert sorted(sim_final) == sorted(mqtt_final)
for k in sim_final:
    a, b = np.asarray(sim_final[k]), np.asarray(mqtt_final[k])
    assert a.dtype == b.dtype and (a == b).all(), \
        f"{k}: real-broker global diverged from SimBroker"
print(f"final global over MQTT is bit-identical to SimBroker "
      f"({len(sim_final)} tensors) — transport swap certified")
