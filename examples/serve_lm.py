"""Batched serving of a (reduced) assigned architecture: prefill + decode
with KV cache — the same functions the inference dry-run cells lower.

    PYTHONPATH=src python examples/serve_lm.py --arch hymba-1.5b
"""
import argparse

import jax
import numpy as np

from repro.configs.base import get_arch, smoke_config
from repro.models import model_api
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = smoke_config(get_arch(args.arch))
    params = model_api.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_size=4)

    rng = np.random.default_rng(0)
    reqs = [engine.submit(rng.integers(0, cfg.vocab, size=rng.integers(4, 12)),
                          max_new=args.max_new)
            for _ in range(args.requests)]
    done = engine.run()
    for r in done:
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")
    s = engine.stats
    print(f"prefill {s['prefill_tokens']} tok in {s['prefill_s']:.2f}s | "
          f"decode {s['decode_steps']} steps in {s['decode_s']:.2f}s | "
          f"{s['decode_steps'] * 4 / max(s['decode_s'], 1e-9):.1f} tok/s")


if __name__ == "__main__":
    main()
