"""Dynamic role management under churn — the paper's core mechanism.

Watch the coordinator rearrange aggregator roles as client stats drift,
clients die (LWT -> failure detector), and new clients join; each round
prints the cluster heads and exactly which clients received role messages.

    PYTHONPATH=src python examples/elastic_roles.py
"""
import numpy as np

from repro.core.broker import SimBroker
from repro.core.client import SDFLMQClient
from repro.core.coordinator import Coordinator, CoordinatorConfig
from repro.core.parameter_server import ParameterServer
from repro.core.stats import StatsSimulator

N, ROUNDS = 10, 6
broker = SimBroker()
coord = Coordinator(broker, CoordinatorConfig(role_policy="perf_aware",
                                              aggregator_ratio=0.3))
ps = ParameterServer(broker)
sim = StatsSimulator([f"c{i}" for i in range(N + 2)], seed=7)
clients = {f"c{i}": SDFLMQClient(f"c{i}", broker,
                                 stats=sim.sample(f"c{i}", 0))
           for i in range(N)}
clients["c0"].create_fl_session("s", "m", ROUNDS, N, N + 2)
for i in range(1, N):
    clients[f"c{i}"].join_fl_session("s", "m")
coord.expire_waiting("s")   # waiting time elapsed: start at quorum

p = {"w": np.zeros(8, np.float32)}
for r in range(ROUNDS):
    heads = sorted({c.head for c in coord.tree_of("s").all_clusters()})
    before = coord.rearrangement_messages
    print(f"round {r}: heads={heads}")
    if r == 2:
        print("  !! c3 dies abnormally (LWT fires)")
        clients.pop("c3").fail()
    if r == 4:
        print("  ++ c10 joins elastically")
        nc = SDFLMQClient("c10", broker, stats=sim.sample("c10", 0))
        nc.join_fl_session("s", "m")
        coord._arrange("s", rearrange=True)
        clients["c10"] = nc
    for cid, cl in sorted(clients.items()):
        cl.set_model("s", p, 1)
    for cid, cl in sorted(clients.items()):
        cl.send_local("s")
    for cid, cl in sorted(clients.items()):
        st = sim.sample(cid, r + 1)
        st.last_round_s = float(np.random.default_rng(r).uniform(0.5, 3))
        cl.signal_ready("s", stats=st)
    print(f"  role messages this round: "
          f"{coord.rearrangement_messages - before} "
          f"(vs {len(clients)} clients)")
print("total role changes:",
      sum(c.arbiter.role_changes for c in clients.values()))
