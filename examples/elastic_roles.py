"""Dynamic role management under churn — the paper's core mechanism, driven
through the ``repro.api`` facade over a latency-modeled edge network.

Watch the coordinator rearrange aggregator roles as client stats drift,
clients die (LWT -> failure detector), and new clients join; each round
prints the cluster heads and exactly which clients received role messages.
The broker is wrapped in a ``LatencyTransport`` (per-link delay/jitter), so
the run also reports modeled network time per link.

    PYTHONPATH=src python examples/elastic_roles.py
"""
import numpy as np

from repro.api import Federation
from repro.core.stats import StatsSimulator

N, ROUNDS = 10, 6

fed = Federation(role_policy="perf_aware", aggregator_ratio=0.3,
                 latency=dict(delay_s=0.02, jitter_s=0.01, seed=7))
sim = StatsSimulator([f"c{i}" for i in range(N + 2)], seed=7)
# slow uplink for one client: the perf-aware policy should avoid heading it
fed.transport.set_link("c7", delay_s=0.25, jitter_s=0.05)

clients = [fed.client(f"c{i}", stats=sim.sample(f"c{i}", 0))
           for i in range(N)]
session = fed.create_session("s", "m", rounds=ROUNDS, participants=clients,
                             capacity=(N, N + 2))
session.start()   # waiting time elapsed: start at quorum

p = {"w": np.zeros(8, np.float32)}
coord = fed.coordinator
for r in range(ROUNDS):
    heads = sorted({c.head for c in session.tree().all_clusters()})
    before = coord.rearrangement_messages
    print(f"round {r}: heads={heads}")
    if r == 2:
        print("  !! c3 dies abnormally (LWT fires)")
        session.fail("c3")
    if r == 4:
        print("  ++ c10 joins elastically")
        session.join(fed.client("c10", stats=sim.sample("c10", 0)))

    def stats(cid, round_idx):
        st = sim.sample(cid, round_idx + 1)
        st.last_round_s = float(np.random.default_rng(round_idx).uniform(0.5, 3))
        return st

    session.run_round(lambda cid, g, rnd: (p, 1), stats_fn=stats)
    print(f"  role messages this round: "
          f"{coord.rearrangement_messages - before} "
          f"(vs {len(session.participants)} clients)")

print("total role changes:",
      sum(c.arbiter.role_changes for c in session.participants.values()))
net = fed.broker.sys_stats()
print(f"modeled network time: {net['virtual_time_s']:.2f}s over "
      f"{net['messages_sent']} deliveries; "
      f"c7 mean latency {net['links'].get('c7', {}).get('mean_latency_ms', 0)}ms")
