"""Federated LM training end-to-end: the compiled data plane (pjit FL round
step with hierarchical aggregation) driven by the SDFLMQ control plane.

Trains a reduced Qwen2-family model across 8 simulated clients (non-IID
token streams) on an 8-device host mesh, with checkpointing and a mid-run
client failure that triggers role rearrangement.

    PYTHONPATH=src python examples/federated_lm.py [--rounds 12]

Scale knobs: --full uses the real qwen2-7b config (needs a TPU pod);
--model-dim/--layers size the reduced model (~100M params with
--model-dim 512 --layers 12, still CPU-runnable for a few hundred rounds).
"""
import argparse
import os
import tempfile


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch-per-client", type=int, default=4)
    ap.add_argument("--model-dim", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--strategy", default="fedavg",
                    help="aggregation strategy: fedavg | fedprox | "
                         "trimmed_mean | coordinate_median")
    ap.add_argument("--update-filter", default=None,
                    help="partial-update glob spec, e.g. '*/lora_A,*/lora_B'")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    # Size the host platform to the mesh before jax initialises: data=clients
    # and as many model shards as fit in an 8-ish device budget.
    model = max(1, 8 // args.clients)
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.clients * model}")

    from repro.configs.base import get_arch, smoke_config
    from repro.ft.failures import FailurePlan
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import SDFLMQTrainer

    cfg = get_arch("qwen2-7b")
    if not args.full:
        cfg = smoke_config(cfg)
        if args.model_dim:
            cfg = cfg.replace(d_model=args.model_dim, head_dim=args.model_dim // 4)
        if args.layers:
            cfg = cfg.replace(n_layers=args.layers)
    mesh = make_host_mesh(data=args.clients, model=model)
    ckpt = tempfile.mkdtemp(prefix="fedlm_ckpt_")
    plan = FailurePlan(fail_at={args.rounds // 2: [f"c{args.clients - 1}"]})
    tr = SDFLMQTrainer(cfg, mesh, args.clients, args.rounds,
                       args.batch_per_client, args.seq, ckpt_dir=ckpt,
                       failure_plan=plan, strategy=args.strategy,
                       update_filter=args.update_filter)
    print(f"clients={args.clients} rounds={args.rounds} "
          f"strategy={args.strategy} ckpt={ckpt}")
    for m in tr.run():
        print(f"round {m['round']:3d} loss {m['loss']:.4f} "
              f"({m['time_s']:.2f}s, {m['n_clients']} clients, "
              f"schedule {m['schedule']})")
    print("rearrangement messages:", tr.coord.rearrangement_messages)


if __name__ == "__main__":
    main()
