"""Quickstart — the paper's Listing-1 scenario through the ``repro.api``
facade: no manual broker/coordinator/parameter-server wiring, no hand-rolled
round loop.

``Federation`` owns the infrastructure; ``create_session`` registers the
session with the coordinator (first participant creates, the rest join);
``session.run`` drives local training + hierarchical aggregation over the
cluster tree each round.  The aggregation strategy is selectable by name —
try ``python examples/quickstart.py trimmed_mean`` (robust to a poisoned
client) or ``fedadam`` (server-side adaptive optimizer).

    PYTHONPATH=src python examples/quickstart.py [strategy]

Telemetry (optional): set ``SDFLMQ_METRICS_PORT`` to enable metrics and
serve Prometheus ``/metrics`` + ``/timeline.json`` on that port after the
run (held open for ``SDFLMQ_METRICS_HOLD_S`` seconds, default 10);
``SDFLMQ_TIMELINE_PATH`` additionally writes the round-trace JSON there.
"""
import os
import sys

from repro.api import Federation, list_strategies
from repro.data.federated import FederatedMNIST
from repro.train.mlp import accuracy, init_mlp, train_epochs

FL_ROUNDS = 2
N_CLIENTS = 5
STRATEGY = sys.argv[1] if len(sys.argv) > 1 else "fedavg"
assert STRATEGY in list_strategies(), f"pick one of {list_strategies()}"
METRICS_PORT = os.environ.get("SDFLMQ_METRICS_PORT")

data = FederatedMNIST(N_CLIENTS, frac_per_client=0.01, total=10000)
xt, yt = data.test

# --- one entry point: broker + coordinator + parameter server ------------
fed = Federation(metrics=True if METRICS_PORT else None)
clients = [fed.client(f"client_{i}",
                      preferred_role="aggregator" if i == 0 else "trainer")
           for i in range(N_CLIENTS)]
session = fed.create_session("session_01", model_name="mlp",
                             rounds=FL_ROUNDS, participants=clients,
                             strategy=STRATEGY)


# --- local training callback: (client_id, global, round) -> (params, n) --
def train(client_id, global_params, round_idx):
    i = int(client_id.rsplit("_", 1)[1])
    x, y = data.client_data(i)
    local = train_epochs(global_params, x, y, epochs=5, seed=round_idx)
    return local, data.n_samples(i)


session.on_global_update = lambda params, version: print(
    f"  global v{version}: test acc {accuracy(params, xt, yt):.3f}")
session.on_round_start = lambda rnd: print(f"round {rnd} ({STRATEGY})")

session.run(train, initial_params=init_mlp(seed=0))

tree = session.tree()
print("cluster tree:", [(c.cluster_id, c.head, len(c.members))
                        for c in tree.all_clusters()])
print("broker stats:", fed.broker.sys_stats()["messages_sent"],
      "messages delivered")

if METRICS_PORT:
    import time

    from repro.api import serve_metrics
    from repro.obs import write_timeline_json

    srv = serve_metrics(fed.metrics, tracer=fed.tracer,
                        port=int(METRICS_PORT))
    print(f"telemetry: {srv.url}/metrics ({fed.metrics.series_count()} "
          f"series), {srv.url}/timeline.json")
    timeline_path = os.environ.get("SDFLMQ_TIMELINE_PATH")
    if timeline_path:
        print("timeline:", write_timeline_json(fed.tracer, timeline_path))
    time.sleep(float(os.environ.get("SDFLMQ_METRICS_HOLD_S", "10")))
    srv.stop()
