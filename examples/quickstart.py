"""Quickstart — the paper's Listing 1, working end to end.

A fully connected MLP is trained locally for 5 epochs per round and sent
to the cluster aggregators for global model updating; SDFLMQ appears in
exactly three places (session create/join, send_local, wait_global_update).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.broker import SimBroker
from repro.core.client import SDFLMQClient
from repro.core.coordinator import Coordinator
from repro.core.parameter_server import ParameterServer
from repro.data.federated import FederatedMNIST
from repro.train.mlp import accuracy, init_mlp, train_epochs

FL_ROUNDS = 2
N_CLIENTS = 5

# --- infrastructure (an edge broker + coordinator service) ---------------
broker = SimBroker()
coordinator = Coordinator(broker)
param_server = ParameterServer(broker)
data = FederatedMNIST(N_CLIENTS, frac_per_client=0.01, total=10000)

# --- Setup SDFLMQ clients (paper Listing 1) --------------------------------
fl_clients = []
for i in range(N_CLIENTS):
    fl_client = SDFLMQClient(client_id=f"client_{i}", broker=broker,
                             preferred_role="aggregator" if i == 0 else "trainer")
    fl_clients.append(fl_client)

# USE CODE BELOW TO CREATE A SESSION:
fl_clients[0].create_fl_session(session_id="session_01",
                                model_name="mlp",
                                fl_rounds=FL_ROUNDS,
                                session_capacity_min=N_CLIENTS,
                                session_capacity_max=N_CLIENTS)

# USE CODE BELOW TO JOIN A SESSION:
for fl_client in fl_clients[1:]:
    fl_client.join_fl_session(session_id="session_01", model_name="mlp",
                              fl_rounds=FL_ROUNDS)

# --- Optimization loop ------------------------------------------------------
model = init_mlp(seed=0)
xt, yt = data.test
for rnd in range(FL_ROUNDS):
    for i, fl_client in enumerate(fl_clients):
        x, y = data.client_data(i)
        local = train_epochs(model, x, y, epochs=5, seed=rnd)   # local training
        # Federated learning
        fl_client.set_model("session_01", local, n_samples=data.n_samples(i))
    for fl_client in fl_clients:
        fl_client.send_local("session_01")
    model = fl_clients[0].wait_global_update("session_01")
    print(f"round {rnd}: global model v{fl_clients[0].models.get('session_01').global_version}"
          f" test acc {accuracy(model, xt, yt):.3f}")
    for fl_client in fl_clients:           # round-status update (§III-E4)
        fl_client.signal_ready("session_01")

tree = coordinator.tree_of("session_01")
print("cluster tree:", [(c.cluster_id, c.head, len(c.members))
                        for c in tree.all_clusters()])
print("broker stats:", broker.sys_stats()["messages_sent"], "messages delivered")
