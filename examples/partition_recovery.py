"""A 2-cluster federation surviving a network partition — and reconverging.

Eight edge clients sit in two sites (A: c0-c3, B: c4-c7).  At t=3s on the
virtual clock the backhaul of site B drops: site B can reach neither the
coordinator nor site A, so its model updates and readiness signals are held
by the transport.  The round deadline keeps the federation alive — the
coordinator cuts each partitioned round after 0.5 virtual seconds and the
global model renormalizes over site A alone.  At t=6s the link heals:
held traffic floods in (stale rounds are discarded, not folded in), site B
re-joins the aggregation, and the global reconverges to the all-client
optimum.

Every client's "training" pulls the global toward its private optimum, so
the distance between the global model and the fleet mean makes the
partition (and the recovery) directly visible.

    PYTHONPATH=src python examples/partition_recovery.py
"""
import numpy as np

from repro.api import Federation, scenarios

N, ROUNDS = 8, 10
SITE_A = [f"c{i}" for i in range(4)]
SITE_B = [f"c{i}" for i in range(4, N)]

rng = np.random.default_rng(0)
optima = {cid: rng.normal(loc=(i < 4) * 2.0 - 1.0, scale=0.2, size=4)
          .astype(np.float32) for i, cid in enumerate(SITE_A + SITE_B)}
fleet_mean = np.mean(list(optima.values()), axis=0)
site_a_mean = np.mean([optima[c] for c in SITE_A], axis=0)

fed = Federation(latency=dict(delay_s=0.01, jitter_s=0.002, seed=7),
                 aggregator_ratio=0.4,
                 round_deadline_s=0.5, flush_spacing_s=0.05)
clients = [fed.client(c) for c in SITE_A + SITE_B]
session = fed.create_session("edge", "toy", rounds=ROUNDS,
                             participants=clients)

# site B loses the coordinator AND site A between t=3 and t=6 (rounds 3-5)
cut = scenarios.partition([["coordinator", "param_server"] + SITE_A, SITE_B],
                          t0=3.0, t1=6.0)


def train(cid, global_params, round_idx):
    base = np.zeros(4, np.float32) if global_params is None \
        else np.asarray(global_params["w"])
    local = base + 0.5 * (optima[cid] - base)        # one local SGD step
    return {"w": local.astype(np.float32)}, 1


def on_update(params, version):
    d_fleet = float(np.linalg.norm(params["w"] - fleet_mean))
    d_site_a = float(np.linalg.norm(params["w"] - site_a_mean))
    t = fed.clock.now
    state = "PARTITIONED" if 3.0 <= t < 6.0 else "healthy"
    print(f"  t={t:5.2f}s v{version:<2d} [{state:11s}] "
          f"|g - fleet_mean|={d_fleet:.3f}  |g - siteA_mean|={d_site_a:.3f}")


session.on_global_update = on_update
report = scenarios.play(session, train, events=[cut], rounds=ROUNDS,
                        round_time_s=1.0,
                        initial_params={"w": np.zeros(4, np.float32)})

g = session.global_params()["w"]
print(f"\nrounds completed: {report.rounds_completed}/{ROUNDS} "
      f"(deadline cuts: {report.deadline_cuts}, "
      f"held in partition: {report.partition_held}, "
      f"stale dropped: {report.stale_dropped})")
print(f"final |global - fleet_mean| = {np.linalg.norm(g - fleet_mean):.4f} "
      f"(reconverged: {np.linalg.norm(g - fleet_mean) < 0.15})")
assert report.final_state == "terminated" and not report.stalled
assert np.linalg.norm(g - fleet_mean) < 0.15, "did not reconverge after heal"
