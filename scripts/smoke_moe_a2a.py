"""ep_a2a MoE vs auto (einsum) MoE: same routing => same outputs (up to
capacity-drop differences at the margins) + gradient flow."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch, smoke_config
from repro.dist import sharding as shd
from repro.models.moe import moe_apply, moe_decl

mesh = jax.make_mesh((2, 4), ("data", "model"))
base = smoke_config(get_arch("kimi-k2-1t-a32b"))
# E=4 divisible by model=4; generous capacity so neither path drops
cfg = base.replace(moe=dataclasses.replace(base.moe, n_experts=4, top_k=2,
                                           capacity_factor=8.0,
                                           n_shared_experts=1))
cfg_a2a = cfg.replace(moe=dataclasses.replace(cfg.moe, impl="ep_a2a"))

key = jax.random.PRNGKey(0)
p = shd.materialize(moe_decl(cfg), key)
x = jax.random.normal(key, (4, 16, cfg.d_model), jnp.float32).astype(jnp.bfloat16)

with mesh:
    y_auto, aux_a = jax.jit(lambda p, x: moe_apply(cfg, p, x))(p, x)
    y_a2a, aux_b = jax.jit(lambda p, x: moe_apply(cfg_a2a, p, x))(p, x)
    # gradients flow
    g = jax.jit(jax.grad(lambda p, x: jnp.sum(
        moe_apply(cfg_a2a, p, x)[0].astype(jnp.float32))))(p, x)

np.testing.assert_allclose(np.asarray(y_auto, np.float32),
                           np.asarray(y_a2a, np.float32), rtol=0.15, atol=0.05)
close = np.isclose(np.asarray(y_auto, np.float32),
                   np.asarray(y_a2a, np.float32), rtol=0.1, atol=0.02).mean()
assert close > 0.95, close
gn = sum(float(jnp.sum(jnp.abs(l.astype(jnp.float32))))
         for l in jax.tree_util.tree_leaves(g))
assert np.isfinite(gn) and gn > 0
print(f"ep_a2a == auto ({close:.1%} close), grad norm finite: {gn:.1f}")
print("MOE A2A OK")
