import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, get_arch
from repro.launch.dryrun import input_specs, lower_cell, make_schedule
from repro.launch.mesh import make_production_mesh
from repro.core.fl_step import build_fl_round_step

arch = sys.argv[1] if len(sys.argv) > 1 else "qwen2-7b"
shape_name = sys.argv[2] if len(sys.argv) > 2 else "train_4k"

cfg = get_arch(arch)
shape = SHAPES[shape_name]
mesh = make_production_mesh()
specs = input_specs(cfg, shape, mesh)

sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

def per_dev_bytes(st):
    n = int(np.prod(st.shape)) if st.shape else 1
    b = n * st.dtype.itemsize
    spec = st.sharding.spec
    div = 1
    for p in spec:
        if p is None:
            continue
        for ax in (p if isinstance(p, tuple) else (p,)):
            div *= sizes[ax]
    return b / div

tot = 0.0
items = []
for path, st in jax.tree_util.tree_flatten_with_path(specs)[0]:
    b = per_dev_bytes(st)
    tot += b
    items.append((b, jax.tree_util.keystr(path), st.shape, str(st.sharding.spec)))
items.sort(reverse=True)
print(f"TOTAL input bytes/device: {tot/2**30:.2f} GiB")
for b, k, shp, sp in items[:12]:
    print(f"  {b/2**30:7.3f} GiB {k} {shp} {sp}")

# lower and find biggest temp allocations
if shape.kind == "train":
    sched = make_schedule(cfg, mesh)
    fn = jax.jit(build_fl_round_step(cfg, mesh, sched), donate_argnums=(0,))
    with mesh:
        lowered = fn.lower(specs["state"], specs["batch"], specs["weights"])
    comp = lowered.compile()
    ma = comp.memory_analysis()
    print("mem analysis:", {k: f"{getattr(ma, k)/2**30:.2f}GiB" for k in
          ("argument_size_in_bytes", "output_size_in_bytes",
           "temp_size_in_bytes", "alias_size_in_bytes")})

    # find biggest tensors in optimized HLO
    import re
    from collections import Counter
    txt = comp.as_text()
    pat = re.compile(r"(bf16|f32|s32|pred|u32|s8)\[([0-9,]+)\]")
    DT = {"bf16": 2, "f32": 4, "s32": 4, "pred": 1, "u32": 4, "s8": 1}
    best = []
    for line in txt.splitlines():
        m = pat.search(line)
        if not m:
            continue
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            n *= int(d)
        b = n * DT[dt]
        if b > 2**30:
            op = line.strip().split(" = ")[0][-60:]
            kind = line.split(" = ")[1].split("(")[0][:60] if " = " in line else "?"
            best.append((b, f"{dt}[{dims}]", kind.strip()))
    best.sort(reverse=True)
    seen = set()
    for b, shp, kind in best:
        if (shp, kind.split()[-1] if kind else "") in seen:
            continue
        seen.add((shp, kind.split()[-1] if kind else ""))
        print(f"  TEMP {b/2**30:7.2f} GiB {shp:40s} {kind}")
        if len(seen) > 15:
            break
