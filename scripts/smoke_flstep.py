"""Dev loop: compiled FL round step on an 8-device host mesh (4 clients x
2-way TP), tree vs flat schedule equivalence, aggregation broadcasts."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_arch, smoke_config
from repro.core.clustering import build_tree
from repro.core.fl_step import (abstract_state, build_fl_round_step,
                                init_state, n_clients_for)
from repro.core.topology import compile_tree, flat_schedule, validate_schedule
from repro.models import inputs as minputs

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = smoke_config(get_arch("qwen2-7b"))
shape = ShapeConfig("t", 32, 8, "train")

C = n_clients_for(cfg, mesh)
print("clients:", C)
clients = [f"c{i}" for i in range(C)]
tree = build_tree("s", clients, clients, aggregator_ratio=0.5, levels=3)
sched = compile_tree(tree)
assert not validate_schedule(sched), validate_schedule(sched)
print("schedule:", sched.kind, "levels:", len(sched.level_groups),
      sched.level_groups, sched.head_masks)

key = jax.random.PRNGKey(0)
with mesh:
    state = init_state(cfg, mesh, key)
    batch = minputs.make_batch(cfg, shape, key, clients=C)
    weights = jnp.arange(1.0, C + 1.0)

    step_tree = jax.jit(build_fl_round_step(cfg, mesh, sched))
    step_flat = jax.jit(build_fl_round_step(cfg, mesh, flat_schedule(C)))

    s1, m1 = step_tree(state, batch, weights)
    s2, m2 = step_flat(state, batch, weights)

# all clients hold identical params after aggregation
p1 = jax.device_get(s1["params"]["embed"]["in_table"])
assert np.allclose(p1[0], p1[1]) and np.allclose(p1[0], p1[-1])
# tree == flat (same weighted mean)
l1 = jax.tree_util.tree_leaves(s1["params"])
l2 = jax.tree_util.tree_leaves(s2["params"])
for a, b in zip(l1, l2):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-2, atol=1e-3)
print("tree == flat aggregation ✓  loss:", float(m1["loss"]))

# abstract state lowers
astate = abstract_state(cfg, mesh, "adamw")
print("abstract state OK:",
      jax.tree_util.tree_structure(astate["params"]).num_leaves, "param leaves")
print("ALL FL-STEP CHECKS PASSED")
