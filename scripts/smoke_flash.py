"""Flash attention vs full attention: forward + gradients, causal/window."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import flash_attention, full_attention

key = jax.random.PRNGKey(0)
for (B, S, H, K, hd, causal, window) in [
    (2, 128, 4, 2, 16, True, None),
    (1, 200, 6, 6, 32, True, 64),      # non-divisible by chunks + SWA
    (2, 96, 4, 1, 8, False, None),     # bidirectional (encoder/cross)
]:
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    pos = jnp.arange(S)

    def loss_flash(q, k, v):
        o = flash_attention(q, k, v, causal, window, 32, 48)
        return jnp.sum(o * jnp.cos(jnp.arange(o.size).reshape(o.shape) * 0.01))

    def loss_full(q, k, v):
        o = full_attention(q, k, v, pos, pos, causal=causal, window=window)
        return jnp.sum(o * jnp.cos(jnp.arange(o.size).reshape(o.shape) * 0.01))

    o1 = flash_attention(q, k, v, causal, window, 32, 48)
    o2 = full_attention(q, k, v, pos, pos, causal=causal, window=window)
    np.testing.assert_allclose(o1, o2, rtol=2e-4, atol=2e-5)

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_full, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4,
                                   err_msg=f"d{name} mismatch")
    print(f"OK B={B} S={S} H={H} K={K} causal={causal} window={window}")
print("FLASH == FULL (fwd + grads)")
