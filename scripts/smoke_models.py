"""Fast dev loop: forward + prefill + decode for every arch's smoke config."""
import sys

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ShapeConfig, get_arch, list_archs, smoke_config
from repro.dist import sharding as shd
from repro.models import inputs, model_api

SMOKE_SHAPE = ShapeConfig("smoke", 32, 4, "train")


def run(name: str) -> None:
    cfg = smoke_config(get_arch(name))
    key = jax.random.PRNGKey(0)
    params = model_api.init_params(cfg, key)
    n = shd.param_count(model_api.param_decls(cfg))
    batch = inputs.make_batch(cfg, SMOKE_SHAPE, key)
    mod = model_api.get_model(cfg)

    logits, aux = jax.jit(lambda p, b: mod.forward(cfg, p, b))(params, batch)
    assert logits.shape == (4, 32, ((cfg.vocab + 127) // 128) * 128), logits.shape
    assert not jnp.isnan(logits).any(), "NaN in forward logits"

    loss, parts = model_api.loss_fn(cfg, params, batch)
    assert jnp.isfinite(loss), loss

    # prefill + one decode step
    pre_shape = ShapeConfig("smoke_pre", 32, 4, "prefill")
    pbatch = inputs.make_batch(cfg, pre_shape, key)
    plog, cache = jax.jit(lambda p, b: mod.prefill(cfg, p, b))(params, pbatch)
    assert not jnp.isnan(plog).any(), "NaN in prefill logits"

    dbatch = {"token": jnp.zeros((4, 1), jnp.int32),
              "pos": jnp.full((4,), 32, jnp.int32)}
    dlog, cache2 = jax.jit(lambda p, c, b: mod.decode_step(cfg, p, c, b))(
        params, cache, dbatch)
    assert not jnp.isnan(dlog).any(), "NaN in decode logits"
    print(f"  OK {name:20s} params={n:,} loss={float(loss):.3f}")


if __name__ == "__main__":
    names = sys.argv[1:] or list_archs()
    for nm in names:
        try:
            run(nm)
        except Exception as e:
            print(f"  FAIL {nm}: {type(e).__name__}: {e}")
            raise
