"""Dev loop: full FL session over the sim broker — 8 clients, 3 rounds,
hierarchical clusters, FedAvg equivalence vs flat oracle, failure + role
rearrangement."""
import numpy as np

from repro.core.broker import SimBroker
from repro.core.client import SDFLMQClient
from repro.core.coordinator import Coordinator, CoordinatorConfig
from repro.core.parameter_server import ParameterServer
from repro.core.stats import StatsSimulator

N, ROUNDS = 8, 3
rng = np.random.default_rng(0)

broker = SimBroker()
coord = Coordinator(broker, CoordinatorConfig(role_policy="memory_aware",
                                              aggregator_ratio=0.3, levels=3))
ps = ParameterServer(broker)
sim = StatsSimulator([f"c{i}" for i in range(N)])

clients = {}
for i in range(N):
    cid = f"c{i}"
    clients[cid] = SDFLMQClient(cid, broker, preferred_role="aggregator" if i < 3 else "trainer",
                                stats=sim.sample(cid, 0))

creator = clients["c0"]
creator.create_fl_session("s1", "mlp", fl_rounds=ROUNDS,
                          session_capacity_min=N, session_capacity_max=N)
for i in range(1, N):
    clients[f"c{i}"].join_fl_session("s1", "mlp")

sess = coord.sessions["s1"]
print("state:", sess.state, "round:", sess.round_idx)
assert sess.state.value == "running", sess.state

# local "training": each client's params = const(i); weights = samples
local = {}
for i, (cid, cl) in enumerate(sorted(clients.items())):
    p = {"w": np.full((4, 4), float(i), np.float32), "b": np.arange(4, dtype=np.float32) * i}
    n = (i + 1) * 10
    local[cid] = (p, n)
    cl.set_model("s1", p, n_samples=n)

# oracle flat FedAvg
tw = sum(n for _, n in local.values())
oracle_w = sum(p["w"] * n for p, n in local.values()) / tw
oracle_b = sum(p["b"] * n for p, n in local.values()) / tw

for r in range(ROUNDS):
    for cid, cl in sorted(clients.items()):
        cl.send_local("s1")
    g = ps.get_global("s1")
    assert g is not None, "no global model stored"
    err = np.abs(g["params"]["w"] - oracle_w).max()
    print(f"round {r}: global version={g['version']} err={err:.2e} "
          f"tree_levels={len(coord.tree_of('s1').levels)}")
    assert err < 1e-5, err
    assert np.abs(g["params"]["b"] - oracle_b).max() < 1e-5
    for cid, cl in sorted(clients.items()):
        # re-set local params (same) to keep oracle fixed across rounds
        cl.set_model("s1", local[cid][0], n_samples=local[cid][1])
        cl.signal_ready("s1", stats=sim.sample(cid, r + 1))

print("rearrangement msgs:", coord.rearrangement_messages,
      "arrangement msgs:", coord.arrangement_messages)
print("session state:", sess.state)
assert sess.state.value == "terminated"

# ---- failure handling: new session, kill a client mid-round -------------
broker2 = SimBroker()
coord2 = Coordinator(broker2, CoordinatorConfig(levels=2))
ps2 = ParameterServer(broker2)
cl2 = {f"d{i}": SDFLMQClient(f"d{i}", broker2, stats=sim.sample(f"c{i % N}", 0))
       for i in range(5)}
cl2["d0"].create_fl_session("s2", "m", 2, 5, 5)
for i in range(1, 5):
    cl2[f"d{i}"].join_fl_session("s2", "m")
assert coord2.sessions["s2"].state.value == "running"
for cid, c in cl2.items():
    c.set_model("s2", {"w": np.ones(3, np.float32)}, 1)
cl2["d4"].fail()  # LWT -> coordinator removes + rearranges
assert "d4" not in coord2.sessions["s2"].contributors
for cid, c in cl2.items():
    if cid != "d4":
        c.send_local("s2")
g2 = ps2.get_global("s2")
assert g2 is not None and np.allclose(g2["params"]["w"], 1.0)
print("failure handling OK; broker stats:", broker.sys_stats()["messages_sent"], "msgs")
print("ALL CONTROL-PLANE CHECKS PASSED")
