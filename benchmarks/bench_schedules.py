"""Compiled aggregation-schedule comparison: collective wire bytes of the
FL round step under tree / flat / rs_ag schedules (reads the dry-run JSON
records when present; otherwise lowers a small cell in-process — requires
the 512-device env, so prefer the dryrun artifacts)."""
from __future__ import annotations

import glob
import json
import os


def run(verbose: bool = True):
    rows = []
    base = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "dryrun")
    recs = []
    for path in sorted(glob.glob(os.path.join(base, "*train_4k*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            recs.append(r)
    for r in recs:
        rf = r["roofline"]
        rows.append(("dryrun_train_cell", rf["collective_s"] * 1e6, {
            "arch": r["arch"], "mesh": r["mesh"],
            "schedule": r.get("schedule", "tree"),
            "coll_GB": round(rf["collective_bytes"] / 1e9, 2),
            "dominant": rf["dominant"],
            "roofline_fraction": round(rf["roofline_fraction"], 3),
        }))
    if verbose:
        for name, us, d in rows:
            print(f"  {d['arch']:>18s} {d['mesh']:>8s} sched={d['schedule']:>5s} "
                  f"coll={d['coll_GB']}GB dom={d['dominant']} "
                  f"frac={d['roofline_fraction']}")
    if not rows:
        rows.append(("dryrun_train_cell", 0.0,
                     {"note": "run launch.dryrun --all first"}))
    return rows


if __name__ == "__main__":
    run()
