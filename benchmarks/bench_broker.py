"""Paper §VI "load": broker throughput, MQTTFC batching + compression
overhead, LatencyTransport decoration cost, role-rearrangement message cost
(the paper's "negligible cost" claim quantified)."""
from __future__ import annotations

import time

import numpy as np

from repro.api import Federation, LatencyTransport
from repro.core.broker import SimBroker
from repro.core.mqttfc import MQTTFC
from repro.core.stats import StatsSimulator


def bench_raw_throughput(n_msgs: int = 20000):
    b = SimBroker()
    sink = [0]
    b.connect("c", lambda m: sink.__setitem__(0, sink[0] + 1))
    b.subscribe("c", "t/#")
    payload = b"x" * 256
    t0 = time.perf_counter()
    for i in range(n_msgs):
        b.publish("t/a", payload)
    dt = time.perf_counter() - t0
    return ("broker_throughput", dt / n_msgs * 1e6,
            {"msgs_per_s": round(n_msgs / dt), "payload_b": 256})


def bench_batching(payload_mb: float = 4.0):
    b = SimBroker()
    rx = MQTTFC(b, "rx", max_batch_bytes=64 * 1024)
    tx = MQTTFC(b, "tx", max_batch_bytes=64 * 1024)
    got = []
    rx.bind("t/m", lambda a: got.append(a))
    arr = np.random.default_rng(0).normal(
        size=(int(payload_mb * 2**20 // 8),)).astype(np.float64)
    t0 = time.perf_counter()
    tx.call("t/m", arr)
    dt = time.perf_counter() - t0
    assert got and got[0].shape == arr.shape
    return ("mqttfc_batching", dt * 1e6,
            {"payload_mb": payload_mb, "parts": tx.parts_sent,
             "mb_per_s": round(payload_mb / dt, 1)})


def bench_compression():
    b = SimBroker()
    rx = MQTTFC(b, "rx")
    txs = {}
    out = {}
    for codec in ("zlib", "zstd", "none"):
        tx = MQTTFC(b, f"tx_{codec}", codec=codec,
                    compress_threshold=0 if codec != "none" else 1 << 60)
        # structured model-like payload (compressible)
        arr = (np.arange(2**18, dtype=np.float32) % 997) / 997
        rx.bind(f"t/{codec}", lambda a: None)
        t0 = time.perf_counter()
        tx.call(f"t/{codec}", arr)
        dt = time.perf_counter() - t0
        out[codec] = {"ratio": round(tx.raw_bytes_sent / max(tx.bytes_sent, 1), 2),
                      "us": round(dt * 1e6)}
    return ("mqttfc_compression", out["zlib"]["us"], out)


def bench_fanout_1k(n_subs: int = 1000, n_msgs: int = 200):
    """Many-subscriber routing: 1k clients x 3 filters (exact, ``+``
    wildcard, shared ``#`` broadcast).  The pre-trie broker paid an
    O(clients x filters) ``topic_matches`` scan per publish; the trie +
    per-topic match cache makes routing O(topic levels)."""
    b = SimBroker()
    sink = [0]
    for i in range(n_subs):
        b.connect(f"c{i}", lambda m: sink.__setitem__(0, sink[0] + 1))
        # mixed filter shapes: exact, single-level wildcard, deep wildcard
        b.subscribe(f"c{i}", f"t/{i}/x")
        b.subscribe(f"c{i}", f"t/{i}/+")
        b.subscribe(f"c{i}", "bcast/#")
    payload = b"x" * 256
    t0 = time.perf_counter()
    for i in range(n_msgs):
        b.publish(f"t/{i % n_subs}/x", payload)
    for i in range(n_msgs):
        b.publish("bcast/all", payload)
    dt = time.perf_counter() - t0
    return ("broker_fanout_1k", dt / (2 * n_msgs) * 1e6,
            {"subs": 3 * n_subs, "msgs_per_s": round(2 * n_msgs / dt),
             "deliveries": sink[0]})


def bench_latency_transport_overhead(n_msgs: int = 20000):
    """Decoration cost of the per-link latency model on the hot path."""
    b = LatencyTransport(SimBroker(), delay_s=0.01, jitter_s=0.005)
    sink = [0]
    b.connect("c", lambda m: sink.__setitem__(0, sink[0] + 1))
    b.subscribe("c", "t/#")
    payload = b"x" * 256
    t0 = time.perf_counter()
    for i in range(n_msgs):
        b.publish("t/a", payload, sender="c")
    dt = time.perf_counter() - t0
    return ("latency_transport_overhead", dt / n_msgs * 1e6,
            {"msgs_per_s": round(n_msgs / dt),
             "virtual_time_s": round(b.virtual_time_s, 1)})


def bench_event_queue(n_msgs: int = 20000):
    """Cost of the discrete-event delivery path: enqueue n messages on a
    held clock (priority queue, per-link jitter), then drain in timestamp
    order — vs the auto-pump path measured above."""
    from repro.api import SimClock
    clock = SimClock()
    b = LatencyTransport(SimBroker(), delay_s=0.01, jitter_s=0.005,
                         clock=clock)
    sink = [0]
    b.connect("c", lambda m: sink.__setitem__(0, sink[0] + 1))
    b.subscribe("c", "t/#")
    payload = b"x" * 256
    t0 = time.perf_counter()
    with clock.hold():
        for i in range(n_msgs):
            b.publish("t/a", payload, sender=f"s{i % 16}")
        clock.run_until_idle()
    dt = time.perf_counter() - t0
    assert sink[0] == n_msgs
    return ("event_queue_drain", dt / n_msgs * 1e6,
            {"msgs_per_s": round(n_msgs / dt), "senders": 16,
             "virtual_time_s": round(clock.now, 2)})


def bench_rearrangement_cost(n_clients: int = 32, rounds: int = 10):
    """Messages for role rearrangement vs full arrangement per round."""
    fed = Federation(role_policy="round_robin")
    sim = StatsSimulator([f"c{i}" for i in range(n_clients)])
    clients = [fed.client(f"c{i}", stats=sim.sample(f"c{i}", 0))
               for i in range(n_clients)]
    session = fed.create_session("s", "m", rounds=rounds,
                                 participants=clients)
    p = {"w": np.zeros(4, np.float32)}
    for r in range(rounds - 1):
        session.run_round(lambda cid, g, rnd: (p, 1),
                          stats_fn=lambda cid, rnd: sim.sample(cid, rnd + 1))
    coord = fed.coordinator
    per_round = coord.rearrangement_messages / max(rounds - 1, 1)
    return ("role_rearrangement_cost", per_round,
            {"clients": n_clients,
             "initial_arrangement_msgs": coord.arrangement_messages,
             "rearrangement_msgs_per_round": round(per_round, 1),
             "fraction_of_full": round(per_round / n_clients, 3)})


def run(verbose: bool = True):
    rows = [bench_raw_throughput(), bench_batching(), bench_compression(),
            bench_fanout_1k(),
            bench_latency_transport_overhead(), bench_event_queue(),
            bench_rearrangement_cost()]
    if verbose:
        for name, us, d in rows:
            print(f"  {name}: {d}")
    return rows


if __name__ == "__main__":
    run()
