"""Kernel microbenches (CPU timings of the oracle/XLA paths + interpret-mode
correctness cost; real MXU timings require a TPU — see EXPERIMENTS.md)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fedavg.ops import fedavg
from repro.kernels.quant8.ops import quantize
from repro.kernels.wkv6.ops import wkv


def _time(fn, *args, reps=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
        else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(verbose: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)

    # fedavg: K clients x 8M params
    for K, N in ((8, 1 << 22), (16, 1 << 22)):
        x = jax.random.normal(key, (K, N), jnp.float32).astype(jnp.bfloat16)
        w = jnp.arange(1.0, K + 1.0)
        dt = _time(lambda a, b: fedavg(a, b, force="ref"), x, w)
        gbps = (K * N * 2) / dt / 1e9
        rows.append(("fedavg_xla", dt * 1e6,
                     {"K": K, "N": N, "read_GBps": round(gbps, 1)}))

    # quant8 throughput
    y = jax.random.normal(key, (1 << 22,), jnp.float32)
    dt = _time(lambda a: _q(a), y)
    rows.append(("quant8_xla", dt * 1e6,
                 {"N": y.size, "GBps": round(y.nbytes / dt / 1e9, 1)}))

    # wkv chunked jnp (production CPU path)
    B, T, H, dk, dv = 2, 512, 8, 64, 64
    ks = jax.random.split(key, 4)
    r = jax.random.normal(ks[0], (B, T, H, dk)) * 0.3
    k2 = jax.random.normal(ks[1], (B, T, H, dk)) * 0.3
    v2 = jax.random.normal(ks[2], (B, T, H, dv))
    wl = -jnp.exp(jax.random.normal(ks[3], (B, T, H, dk)) * 0.3)
    u = jnp.zeros((H, dk))
    dt = _time(lambda *a: wkv(*a, chunk=64, force="ref")[0], r, k2, v2, wl, u)
    toks = B * T
    rows.append(("wkv6_chunked_xla", dt * 1e6,
                 {"tokens": toks, "tok_per_s": round(toks / dt)}))

    if verbose:
        for name, us, d in rows:
            print(f"  {name}: {us:.0f}us {d}")
    return rows


def _q(a):
    q, s, _ = quantize(a, force="ref")
    return q


if __name__ == "__main__":
    run()
