"""Benchmark runner: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (plus per-bench detail)."""
from __future__ import annotations

import json
import sys
import traceback

from benchmarks import (bench_broker, bench_convergence, bench_kernels,
                        bench_memory, bench_schedules, bench_topology)

SUITES = [
    ("fig7_convergence", bench_convergence),
    ("fig8_topology", bench_topology),
    ("broker_load", bench_broker),
    ("aggregator_memory", bench_memory),
    ("kernels", bench_kernels),
    ("schedules", bench_schedules),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for suite_name, mod in SUITES:
        print(f"# --- {suite_name} ---", file=sys.stderr)
        try:
            rows = mod.run(verbose=True)
        except Exception as e:
            failures += 1
            traceback.print_exc()
            rows = [(suite_name + "_FAILED", 0.0, {"error": str(e)[:200]})]
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{json.dumps(derived)}")
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
