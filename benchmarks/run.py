"""Benchmark runner: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (plus per-bench detail).

``--json PATH`` additionally dumps every row as a JSON artifact (the CI
smoke job uploads this as ``BENCH_pr3.json`` so the perf trajectory is
tracked per PR).  ``SMOKE=1`` shrinks payload sizes for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from benchmarks import (bench_async, bench_broker, bench_convergence,
                        bench_edge_lm, bench_fleet, bench_kernels,
                        bench_memory, bench_schedules, bench_topology,
                        bench_wire)

SUITES = [
    ("fig7_convergence", bench_convergence),
    ("fig8_topology", bench_topology),
    ("fleet", bench_fleet),
    ("broker_load", bench_broker),
    ("wire_data_plane", bench_wire),
    ("async_fl", bench_async),
    ("aggregator_memory", bench_memory),
    ("kernels", bench_kernels),
    ("schedules", bench_schedules),
    ("edge_lm", bench_edge_lm),
]


def _obs_registry_probe() -> dict:
    """One instrumented mini-round: record the telemetry registry's shape
    (series count, subsystems covered, trace volume) into the bench JSON so
    the observability surface is tracked per PR alongside the perf rows."""
    import numpy as np
    from repro.api import Federation
    fed = Federation(metrics=True)
    clients = [fed.client(f"c{i}") for i in range(4)]
    session = fed.create_session("s", "m", rounds=1, participants=clients)
    p = {"w": np.ones(64, np.float32)}
    session.run_round(lambda cid, g, r: (p, 1))
    snap = fed.metrics.snapshot()
    return {
        "series": fed.metrics.series_count(),
        "families": len(snap),
        "subsystems": sorted({name.split("_")[1] for name in snap}),
        "trace_events": fed.tracer.emitted,
        "trace_kinds": fed.tracer.kinds(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write all rows to this JSON file")
    ap.add_argument("--suite", default=None,
                    help="run only the named suite")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    all_rows: dict[str, dict] = {}
    for suite_name, mod in SUITES:
        if args.suite and suite_name != args.suite:
            continue
        print(f"# --- {suite_name} ---", file=sys.stderr)
        try:
            rows = mod.run(verbose=True)
        except Exception as e:
            failures += 1
            traceback.print_exc()
            rows = [(suite_name + "_FAILED", 0.0, {"error": str(e)[:200]})]
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{json.dumps(derived)}")
            all_rows.setdefault(name, {"us": round(us, 1), **derived})
    if not args.suite or args.suite == "wire_data_plane":
        try:
            all_rows["obs_registry"] = _obs_registry_probe()
        except Exception as e:                       # never fail the run
            all_rows["obs_registry"] = {"error": str(e)[:200]}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(all_rows, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
