"""Paper §VI "memory": peak aggregator accumulator bytes per client —
hierarchical clustering vs centralized aggregation.  SDFLMQ's claim: the
per-node aggregation memory drops when the load is spread over heads.
Driven through the repro.api facade; "stack" strategies (trimmed_mean)
additionally show the gather-up-the-tree memory cost of robust
aggregation."""
from __future__ import annotations

import numpy as np

from repro.api import Federation
from repro.train.mlp import init_mlp


def run_case(n_clients: int, hierarchical: bool, strategy: str = "fedavg"):
    fed = Federation(
        levels=3 if hierarchical else 1,
        aggregator_ratio=0.3 if hierarchical else 1.0 / n_clients)
    clients = [fed.client(f"c{i}") for i in range(n_clients)]
    session = fed.create_session("s", "m", rounds=1, participants=clients,
                                 strategy=strategy)
    p = init_mlp()
    session.run_round(lambda cid, g, r: (p, 1))
    assert session.global_params() is not None
    peaks = [cl.models.get("s").peak_acc_bytes
             for cl in session.participants.values()]
    return max(peaks), float(np.mean([x for x in peaks if x > 0]))


def run(verbose: bool = True):
    rows = []
    for n in (8, 16, 32):
        max_h, mean_h = run_case(n, True)
        max_c, mean_c = run_case(n, False)
        rows.append(("aggregator_peak_memory", max_h, {
            "clients": n,
            "hier_max_mb": round(max_h / 2**20, 2),
            "central_max_mb": round(max_c / 2**20, 2),
            "saving": round(1 - max_h / max(max_c, 1), 3),
        }))
        if verbose:
            d = rows[-1][2]
            print(f"  n={n}: hier peak {d['hier_max_mb']}MB vs central "
                  f"{d['central_max_mb']}MB (saving {d['saving']:.0%})")
    # robust strategies pay for exactness: contributions are stacked, not
    # summed, so aggregator memory grows with subtree size
    max_r, _ = run_case(16, True, strategy="trimmed_mean")
    max_s, _ = run_case(16, True, strategy="fedavg")
    rows.append(("robust_strategy_memory", max_r, {
        "clients": 16,
        "trimmed_mean_max_mb": round(max_r / 2**20, 2),
        "fedavg_max_mb": round(max_s / 2**20, 2),
        "overhead_x": round(max_r / max(max_s, 1), 2),
    }))
    if verbose:
        d = rows[-1][2]
        print(f"  robust overhead at n=16: trimmed_mean "
              f"{d['trimmed_mean_max_mb']}MB vs fedavg "
              f"{d['fedavg_max_mb']}MB ({d['overhead_x']}x)")
    return rows


if __name__ == "__main__":
    run()
