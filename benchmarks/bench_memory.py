"""Paper §VI "memory": peak aggregator accumulator bytes per client —
hierarchical clustering vs centralized aggregation.  SDFLMQ's claim: the
per-node aggregation memory drops when the load is spread over heads."""
from __future__ import annotations

import numpy as np

from repro.core.broker import SimBroker
from repro.core.client import SDFLMQClient
from repro.core.coordinator import Coordinator, CoordinatorConfig
from repro.core.parameter_server import ParameterServer
from repro.train.mlp import init_mlp


def run_case(n_clients: int, hierarchical: bool):
    b = SimBroker()
    coord = Coordinator(b, CoordinatorConfig(
        levels=3 if hierarchical else 1,
        aggregator_ratio=0.3 if hierarchical else 1.0 / n_clients))
    ps = ParameterServer(b)
    cls = {f"c{i}": SDFLMQClient(f"c{i}", b) for i in range(n_clients)}
    cls["c0"].create_fl_session("s", "m", 1, n_clients, n_clients)
    for i in range(1, n_clients):
        cls[f"c{i}"].join_fl_session("s", "m")
    p = init_mlp()
    for cid, cl in sorted(cls.items()):
        cl.set_model("s", p, 1)
    for cid, cl in sorted(cls.items()):
        cl.send_local("s")
    assert ps.get_global("s") is not None
    peaks = [cl.models.get("s").peak_acc_bytes for cl in cls.values()]
    return max(peaks), float(np.mean([x for x in peaks if x > 0]))


def run(verbose: bool = True):
    rows = []
    for n in (8, 16, 32):
        max_h, mean_h = run_case(n, True)
        max_c, mean_c = run_case(n, False)
        rows.append(("aggregator_peak_memory", max_h, {
            "clients": n,
            "hier_max_mb": round(max_h / 2**20, 2),
            "central_max_mb": round(max_c / 2**20, 2),
            "saving": round(1 - max_h / max(max_c, 1), 3),
        }))
        if verbose:
            d = rows[-1][2]
            print(f"  n={n}: hier peak {d['hier_max_mb']}MB vs central "
                  f"{d['central_max_mb']}MB (saving {d['saving']:.0%})")
    return rows


if __name__ == "__main__":
    run()
