"""Paper Fig. 8: total processing delay of 10 FL rounds — 2-layer
hierarchical SDFL (30% aggregators) vs centralized single aggregator, for
growing client counts.

Two measurements per point:
  * modeled delay — critical-path network/compute model over the coordinator's
    actual cluster tree (per-client bandwidth/speed from the stats simulator;
    aggregation is parallel across heads, sequential per input);
  * wall delay   — real in-process time of moving the payloads through the
    broker (broker load, serialization, batching).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.broker import SimBroker
from repro.core.client import SDFLMQClient
from repro.core.clustering import ClusterTree
from repro.core.coordinator import Coordinator, CoordinatorConfig
from repro.core.parameter_server import ParameterServer
from repro.core.stats import StatsSimulator
from repro.train.mlp import init_mlp

CLIENT_COUNTS = (5, 10, 15, 20, 25, 30)
ROUNDS = 10
# the wall-clock path moves the real (small) MLP through the broker; the
# critical-path model prices a deep-net payload (paper §VII targets large
# DNNs at the edge) so aggregation-point congestion is visible
MODEL_BYTES = 10 * 2**20
WIRE_MODEL = init_mlp()


def modeled_round_delay(tree: ClusterTree, stats: dict) -> float:
    """Critical path: trainer upload -> head RECEIVES K models over its own
    downlink (the serialization the paper's motivation describes: a single
    aggregation point congests) -> accumulate -> upload partial."""
    AGG_PER_INPUT = 0.001          # s per model accumulate

    def xfer_s(cid):
        bw = stats[cid].bandwidth_mbps * 1e6 / 8
        return MODEL_BYTES / bw

    def train_s(cid):
        return 0.25 / stats[cid].cpu_speed

    ready = {cid: train_s(cid) for cid in tree.client_order}
    for lvl in tree.levels:
        for c in lvl:
            arrive = max(ready.get(m, 0.0) + xfer_s(m) for m in c.members)
            # K inbound models serialize on the head's link + K accumulates
            recv = len(c.members) * (xfer_s(c.head) + AGG_PER_INPUT)
            ready[c.head] = max(arrive, recv)
    return ready[tree.root.head]


def run_case(n_clients: int, hierarchical: bool, rounds: int = ROUNDS):
    broker = SimBroker()
    cfgc = CoordinatorConfig(
        levels=3 if hierarchical else 1,
        aggregator_ratio=0.3 if hierarchical else 1.0 / n_clients)
    coord = Coordinator(broker, cfgc)
    ps = ParameterServer(broker)
    sim = StatsSimulator([f"c{i}" for i in range(n_clients)], seed=1)
    clients = {}
    for i in range(n_clients):
        cid = f"c{i}"
        clients[cid] = SDFLMQClient(cid, broker, stats=sim.sample(cid, 0))
    clients["c0"].create_fl_session("fig8", "mlp", rounds, n_clients,
                                    n_clients)
    for i in range(1, n_clients):
        clients[f"c{i}"].join_fl_session("fig8", "mlp")

    p = WIRE_MODEL
    modeled = 0.0
    t0 = time.perf_counter()
    for r in range(rounds):
        tree = coord.tree_of("fig8")
        stats = coord.sessions["fig8"].contributors
        modeled += modeled_round_delay(tree, stats)
        for cid, cl in sorted(clients.items()):
            cl.set_model("fig8", p, n_samples=1)
        for cid, cl in sorted(clients.items()):
            cl.send_local("fig8")
        assert ps.get_global("fig8") is not None
        for cid, cl in sorted(clients.items()):
            cl.signal_ready("fig8", stats=sim.sample(cid, r + 1))
    wall = time.perf_counter() - t0
    return modeled, wall, broker.sys_stats()


def run(verbose: bool = True):
    rows = []
    for n in CLIENT_COUNTS:
        m_h, w_h, st_h = run_case(n, hierarchical=True)
        m_c, w_c, st_c = run_case(n, hierarchical=False)
        rows.append(("fig8_topology_delay", (w_h + w_c) / 2 * 1e6, {
            "clients": n,
            "hier_modeled_s": round(m_h, 3),
            "central_modeled_s": round(m_c, 3),
            "hier_wall_s": round(w_h, 3),
            "central_wall_s": round(w_c, 3),
            "hier_msgs": st_h["messages_sent"],
            "central_msgs": st_c["messages_sent"],
        }))
        if verbose:
            d = rows[-1][2]
            print(f"  n={n:3d} modeled: hier {d['hier_modeled_s']:7.2f}s "
                  f"central {d['central_modeled_s']:7.2f}s | wall: "
                  f"hier {d['hier_wall_s']:.2f}s central {d['central_wall_s']:.2f}s")
    return rows


if __name__ == "__main__":
    run()
