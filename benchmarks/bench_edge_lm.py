"""Edge-LM benchmark (PR 10 headline numbers): what the top-k int8
error-feedback uplink codec buys on LM-scale parameter sets.

Rows:
  * ``edge_lm_uplink_codec`` — payload bytes/round for an ~100M-param
    tensor set, full precision vs ``topk_int8_ef`` (the ≥10x reduction
    gate), measured on the real codec output, plus encode throughput.
  * ``edge_lm_uplink_e2e`` — the same ratio measured end-to-end through a
    live 2-client federation (``codec_stats`` byte accounting == wire).
  * ``edge_lm_kernel_parity`` — the fused int8 dequantize+aggregate Pallas
    kernel vs its jnp oracle (must be bit-exact).
  * ``edge_lm_convergence`` — federated MLP curve, plain vs compressed
    uplink: time-to-target under an edge-uplink time model (compute wall +
    uplink bytes / link bandwidth, the standard time-to-accuracy
    accounting for gradient compression), with the raw per-round curves,
    rounds-to-target, and byte reduction alongside.  The gate requires the
    compressed run to actually reach the target inside the round budget
    AND its modeled time-to-target to stay within 1.25x of full precision.

``SMOKE=1`` shrinks the tensor set (CI); the committed ``BENCH_pr10.json``
is produced by a full run.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.api import Federation
from repro.data.federated import FederatedMNIST
from repro.dist import compression as C
from repro.train.mlp import accuracy, init_mlp, train_epochs

SMOKE = bool(int(os.environ.get("SMOKE", "0")))

DENSITY = 0.01          # uplink top-k density for the LM-scale byte rows
CONV_DENSITY = 0.05     # denser for the small-MLP convergence gate
CONV_ROUNDS = 10        # cheap (seconds) — same budget in SMOKE and full
CONV_CLIENTS = 5
# Edge uplink the time-to-target model charges bytes against.  1 MiB/s is
# a constrained-but-common edge/IoT uplink; per-round link time is the
# per-client share of the round's uplink bytes (clients upload in
# parallel, so the slowest single uplink bounds the round).
EDGE_UPLINK_BPS = 1 << 20
# Modeled local-training seconds per round (identical work in both runs).
# Frozen at the dev-box measurement instead of live wall time so the
# time-to-target ratio is deterministic and machine-independent — a
# loaded CI runner must not be able to move the gate.  Raw wall times are
# still recorded in the JSON row.
EDGE_COMPUTE_S_PER_ROUND = 0.3


def _lm_params(total: int) -> dict:
    """An LM-shaped tensor set (embedding + square blocks) totalling
    ~``total`` f32 parameters."""
    d = 512 if SMOKE else 2048
    rng = np.random.default_rng(0)
    params = {"embed": rng.standard_normal((total // (4 * d), d))
              .astype(np.float32)}
    i = 0
    while sum(v.size for v in params.values()) < total:
        params[f"blocks/{i}/w"] = rng.standard_normal((d, d)) \
            .astype(np.float32)
        i += 1
    return params


def _payload_bytes(params: dict) -> int:
    return sum(np.asarray(v).nbytes for v in params.values())


def bench_uplink_codec():
    total = 2_000_000 if SMOKE else 100_000_000
    params = _lm_params(total)
    n = sum(v.size for v in params.values())
    plain = _payload_bytes(params)
    t0 = time.perf_counter()
    topk = 0
    for v in params.values():
        idx, q, scale, _ = C.quantize_topk_int8_ef(
            v, np.zeros_like(v), DENSITY, xp=np)
        topk += idx.nbytes + q.nbytes + scale.nbytes
    enc_s = time.perf_counter() - t0
    red = plain / topk
    return ("edge_lm_uplink_codec", enc_s * 1e6,
            {"params": n, "density": DENSITY, "plain_bytes": plain,
             "topk_bytes": topk, "reduction_x": round(red, 1),
             "encode_s": round(enc_s, 2),
             "gate_10x": bool(red >= 10.0)})


def _one_round_bytes(uplink_codec, density=DENSITY, n_clients=2) -> int:
    fed = Federation(levels=1, uplink_codec=uplink_codec,
                     topk_density=density)
    clients = [fed.client(f"c{i}") for i in range(n_clients)]
    session = fed.create_session("s", "m", rounds=1, participants=clients)
    rng = np.random.default_rng(1)
    size = 2**18 if SMOKE else 2**22
    m = {"w": rng.standard_normal((size // 256, 256)).astype(np.float32)}
    session.run_round(lambda cid, g, r: (m, 1))
    return sum(fed.clients[c].codec_stats["uplink_bytes"] for c in fed.clients)


def bench_uplink_e2e():
    t0 = time.perf_counter()
    plain = _one_round_bytes(None)
    topk = _one_round_bytes("topk_int8_ef")
    red = plain / topk
    return ("edge_lm_uplink_e2e", (time.perf_counter() - t0) * 1e6,
            {"plain_bytes": plain, "topk_bytes": topk,
             "reduction_x": round(red, 1), "gate_10x": bool(red >= 10.0)})


def bench_kernel_parity():
    import jax.numpy as jnp
    from repro.kernels.fedavg.ops import qagg
    rng = np.random.default_rng(2)
    diffs = []
    t0 = time.perf_counter()
    for shape in ((4, 64, 256), (3, 33, 7), (8, 1, 1024)):
        q = rng.integers(-127, 128, shape).astype(np.int8)
        s = rng.uniform(0.5, 2.0, shape[:-1] + (1,)).astype(np.float32) / 127
        w = rng.uniform(0.5, 2.0, shape[0]).astype(np.float32)
        got = np.asarray(qagg(jnp.asarray(q), jnp.asarray(s),
                              jnp.asarray(w), force="pallas"))
        ref = np.asarray(qagg(jnp.asarray(q), jnp.asarray(s),
                              jnp.asarray(w), force="ref"))
        diffs.append(float(np.max(np.abs(got - ref))))
    return ("edge_lm_kernel_parity", (time.perf_counter() - t0) * 1e6,
            {"max_abs_diff": max(diffs), "bit_exact": max(diffs) == 0.0})


def _curve(data, uplink_codec, density=CONV_DENSITY):
    fed = Federation(aggregator_ratio=0.4, levels=2,
                     uplink_codec=uplink_codec, topk_density=density,
                     topk_warmup_rounds=1)
    clients = [fed.client(f"c{i}") for i in range(CONV_CLIENTS)]
    session = fed.create_session("conv", "mlp", rounds=CONV_ROUNDS,
                                 participants=clients)
    xt, yt = data.test

    def train(cid, g, rnd):
        i = int(cid[1:])
        x, y = data.client_data(i)
        return train_epochs(g, x, y, epochs=5, seed=rnd), data.n_samples(i)

    curve = []
    session.on_global_update = lambda p, v: curve.append(accuracy(p, xt, yt))
    t0 = time.perf_counter()
    session.run(train, initial_params=init_mlp(seed=0))
    wall = time.perf_counter() - t0
    tot = sum(fed.clients[c].codec_stats["uplink_bytes"] for c in fed.clients)
    return curve, wall, tot / CONV_ROUNDS


def _rounds_to(curve, target) -> int:
    for r, a in enumerate(curve):
        if a >= target:
            return r + 1
    return len(curve) + 1          # never reached inside the budget


def _time_to_target(rounds_to, bytes_per_round) -> float:
    """Modeled seconds to target: rounds x (compute + uplink wire time).
    Wire time per round is the per-client uplink share over the modeled
    edge link (uploads run in parallel across clients)."""
    per_round = (EDGE_COMPUTE_S_PER_ROUND
                 + bytes_per_round / CONV_CLIENTS / EDGE_UPLINK_BPS)
    return rounds_to * per_round


def bench_convergence():
    data = FederatedMNIST(CONV_CLIENTS, frac_per_client=0.01, total=20000)
    plain_curve, plain_wall, plain_bpr = _curve(data, None)
    topk_curve, topk_wall, topk_bpr = _curve(data, "topk_int8_ef")
    target = plain_curve[-1] - 0.025
    rp, rt = _rounds_to(plain_curve, target), _rounds_to(topk_curve, target)
    tp = _time_to_target(rp, plain_bpr)
    tt = _time_to_target(rt, topk_bpr)
    ratio = tt / tp
    red = plain_bpr / topk_bpr
    reached = rt <= CONV_ROUNDS          # sentinel rt would game the ratio
    return ("edge_lm_convergence", (plain_wall + topk_wall) * 1e6,
            {"target_acc": round(target, 4),
             "plain_final": round(plain_curve[-1], 4),
             "topk_final": round(topk_curve[-1], 4),
             "plain_curve": [round(a, 4) for a in plain_curve],
             "topk_curve": [round(a, 4) for a in topk_curve],
             "plain_rounds_to_target": rp, "topk_rounds_to_target": rt,
             "plain_time_to_target_s": round(tp, 3),
             "topk_time_to_target_s": round(tt, 3),
             "edge_uplink_bps": EDGE_UPLINK_BPS,
             "edge_compute_s_per_round": EDGE_COMPUTE_S_PER_ROUND,
             "plain_wall_s": round(plain_wall, 2),
             "topk_wall_s": round(topk_wall, 2),
             "time_to_target_ratio": round(ratio, 3),
             "uplink_bytes_per_round_plain": int(plain_bpr),
             "uplink_bytes_per_round_topk": int(topk_bpr),
             "reduction_x": round(red, 1), "density": CONV_DENSITY,
             "gate_10x": bool(red >= 10.0),
             "gate_time_1_25x": bool(reached and ratio <= 1.25)})


def run(verbose: bool = True):
    rows = [bench_uplink_codec(), bench_uplink_e2e(), bench_kernel_parity(),
            bench_convergence()]
    if verbose:
        for name, _, d in rows:
            print(f"  {name}: {d}")
    return rows


if __name__ == "__main__":
    run()
