"""Data-plane microbenchmarks for the zero-copy TensorBundle wire format:
serialize/deserialize vs the legacy msgpack-ExtType codec, streaming
in-place aggregation vs legacy float64-dict weighted_add, and an
end-to-end federated round on each wire format."""
from __future__ import annotations

import os
import time
import tracemalloc

import numpy as np

from repro.api import Federation
from repro.core import mqttfc as F
from repro.core import wire
from repro.core.client import _Accumulator, weighted_add
from repro.core.wire import TensorBundle

SMOKE = bool(int(os.environ.get("SMOKE", "0")))


def _model(mb: float = 4.0) -> dict:
    n = int(mb * 2**20 // 4 // 2)
    rng = np.random.default_rng(0)
    return {"w": rng.normal(size=(n // 256, 256)).astype(np.float32),
            "b": rng.normal(size=n).astype(np.float32)}


def bench_serialize(mb: float = 4.0, reps: int = 5):
    """Flatten-once TensorBundle encode+decode vs legacy msgpack ExtType."""
    params = _model(mb)
    obj = {"params": params, "weight": 3.0}
    t0 = time.perf_counter()
    for _ in range(reps):
        body = wire.encode_body(
            {"params": TensorBundle.from_params(params), "weight": 3.0})
        back = wire.decode_body(body)
        back["params"].views()
    dt_tb = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        F.decode(F.encode(obj))
    dt_leg = (time.perf_counter() - t0) / reps
    return ("wire_serialize", dt_tb * 1e6,
            {"payload_mb": mb, "tb_ms": round(dt_tb * 1e3, 2),
             "legacy_ms": round(dt_leg * 1e3, 2),
             "speedup_x": round(dt_leg / dt_tb, 1)})


def bench_aggregate(mb: float = 4.0, n_contrib: int = 16, reps: int = 3):
    """Streaming in-place flat accumulate vs legacy float64-dict
    weighted_add, over the accumulator's real lifecycle: one duty, many
    rounds (``restart`` keeps the preallocated buffers).  Every
    contribution is a distinct buffer, as on the wire.  Measured for
    weighted leaf sums (w=k) and for the tree's partial-sum merges
    (w=1.0: a single fused cast-add pass)."""
    dicts = []
    rng = np.random.default_rng(0)
    base = _model(mb)
    for _ in range(n_contrib):
        dicts.append({k: v + rng.standard_normal(1).astype(v.dtype)
                      for k, v in base.items()})
    bundles = [TensorBundle.from_params(d) for d in dicts]
    acc = _Accumulator()

    def tb_round(w_of):
        acc.restart()
        for i, b in enumerate(bundles):
            acc.add_sum(b, w_of(i))
            acc.received += 1

    def leg_round(w_of):
        ref = None
        for i, d in enumerate(dicts):
            ref = weighted_add(ref, d, w_of(i))
        return ref

    out = {}
    for label, w_of in (("weighted", lambda i: float(i + 1)),
                        ("partial_merge", lambda i: 1.0)):
        tb_round(w_of); leg_round(w_of)       # warm allocator/pages
        t0 = time.perf_counter()
        for _ in range(reps):
            tb_round(w_of)
        dt_tb = (time.perf_counter() - t0) / reps
        t0 = time.perf_counter()
        for _ in range(reps):
            leg_round(w_of)
        dt_leg = (time.perf_counter() - t0) / reps
        out[label] = (dt_tb, dt_leg)
    dt_tb, dt_leg = out["weighted"]
    dt_tb_p, dt_leg_p = out["partial_merge"]
    return ("wire_aggregate", dt_tb * 1e6,
            {"payload_mb": mb, "contribs": n_contrib,
             "tb_ms": round(dt_tb * 1e3, 2),
             "legacy_ms": round(dt_leg * 1e3, 2),
             "speedup_x": round(dt_leg / dt_tb, 1),
             "partial_tb_ms": round(dt_tb_p * 1e3, 2),
             "partial_legacy_ms": round(dt_leg_p * 1e3, 2),
             "partial_speedup_x": round(dt_leg_p / dt_tb_p, 1)})


def bench_e2e_round(n_clients: int = 8, mb: float = 1.0):
    """One full federated round (train -> tree aggregate -> global) on each
    wire format; same model, same tree."""
    params = _model(mb)
    out = {}
    for fmt in ("tb", "legacy"):
        fed = Federation(levels=3, aggregator_ratio=0.4, wire_format=fmt)
        clients = [fed.client(f"c{i}") for i in range(n_clients)]
        session = fed.create_session("s", "m", rounds=2,
                                     participants=clients)
        session.run_round(lambda cid, g, r: (params, 1))   # warmup round
        t0 = time.perf_counter()
        session.run_round(lambda cid, g, r: (params, 1))
        out[fmt] = time.perf_counter() - t0
    return ("wire_e2e_round", out["tb"] * 1e6,
            {"clients": n_clients, "payload_mb": mb,
             "tb_ms": round(out["tb"] * 1e3, 1),
             "legacy_ms": round(out["legacy"] * 1e3, 1),
             "speedup_x": round(out["legacy"] / out["tb"], 1)})


def bench_alloc_guard(n_clients: int = 6, mb: float = 0.25, rounds: int = 2):
    """Telemetry must be free when off: with tracemalloc filtered to the
    ``repro.obs`` source files, a steady-state metrics-off round loop
    attributes ZERO allocations to the telemetry package (every hook is a
    single ``if obs is not None`` branch).  Also reports the publisher-side
    encode-arena reuse rate for the same loop."""
    import repro.obs                     # imported, but must stay dormant
    obs_dir = os.path.dirname(os.path.abspath(repro.obs.__file__))
    params = _model(mb)
    fed = Federation(levels=2, aggregator_ratio=0.5)
    clients = [fed.client(f"c{i}") for i in range(n_clients)]
    session = fed.create_session("s", "m", rounds=rounds + 1,
                                 participants=clients)
    session.run_round(lambda cid, g, r: (params, 1))       # warm arenas
    tracemalloc.start()
    for _ in range(rounds):
        session.run_round(lambda cid, g, r: (params, 1))
    snap = tracemalloc.take_snapshot()
    tracemalloc.stop()
    total = sum(s.count for s in snap.statistics("filename"))
    obs_allocs = sum(
        s.count for s in snap.filter_traces(
            [tracemalloc.Filter(True, os.path.join(obs_dir, "*"))]
        ).statistics("filename"))
    ws = clients[0].fc.wire_stats()
    return ("wire_alloc_guard", float(obs_allocs),
            {"obs_allocs": obs_allocs, "total_alloc_blocks": total,
             "rounds": rounds, "clients": n_clients,
             "arena_reuse_hits": ws["arena_reuse_hits"],
             "arena_grows": ws["arena_grows"]})


def run(verbose: bool = True):
    mb = 1.0 if SMOKE else 4.0
    rows = [bench_serialize(mb=mb), bench_aggregate(mb=mb),
            bench_e2e_round(mb=0.5 if SMOKE else 1.0),
            bench_alloc_guard(mb=0.1 if SMOKE else 0.25)]
    if verbose:
        for name, us, d in rows:
            print(f"  {name}: {d}")
    return rows


if __name__ == "__main__":
    run()
