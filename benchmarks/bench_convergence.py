"""Paper Fig. 7: MLP accuracy convergence — local training (5% of data)
vs SDFLMQ federated (5 clients x 1% each, aggregated through the cluster
tree over the sim broker, driven by the repro.api facade).

Also compares aggregation strategies on the same fleet with one client
poisoned (sign-flipped update): robust strategies (trimmed_mean,
coordinate_median) should hold accuracy where fedavg degrades.
"""
from __future__ import annotations

import time

import numpy as np

from repro.api import Federation
from repro.data.federated import FederatedMNIST
from repro.train.mlp import accuracy, init_mlp, train_epochs

N_CLIENTS = 5
ROUNDS = 10
EPOCHS = 5


def _federated_curve(data, rounds: int, strategy: str,
                     poison: bool = False) -> list[float]:
    xt, yt = data.test
    fed = Federation(aggregator_ratio=0.4, levels=3)
    clients = [fed.client(f"c{i}") for i in range(N_CLIENTS)]
    session = fed.create_session(f"fig7_{strategy}", "mlp", rounds=rounds,
                                 participants=clients, strategy=strategy)

    def train(cid, global_params, rnd):
        i = int(cid[1:])
        x, y = data.client_data(i)
        local = train_epochs(global_params, x, y, epochs=EPOCHS, seed=rnd)
        if poison and i == N_CLIENTS - 1:
            # byzantine client: sign-flipped, amplified update
            local = {k: -3.0 * np.asarray(v) for k, v in local.items()}
        return local, data.n_samples(i)

    curve = []
    session.on_global_update = lambda p, v: curve.append(accuracy(p, xt, yt))
    session.run(train, initial_params=init_mlp(seed=0))
    return curve


def run(rounds: int = ROUNDS, verbose: bool = True):
    data = FederatedMNIST(N_CLIENTS, frac_per_client=0.01, total=20000)
    xt, yt = data.test

    # ---- offline baseline: one node with 5% of the data ----------------
    xs = np.concatenate([data.client_data(i)[0] for i in range(N_CLIENTS)])
    ys = np.concatenate([data.client_data(i)[1] for i in range(N_CLIENTS)])
    local = init_mlp(seed=0)
    local_curve = []
    for r in range(rounds):
        local = train_epochs(local, xs, ys, epochs=EPOCHS, seed=r)
        local_curve.append(accuracy(local, xt, yt))

    # ---- SDFLMQ federated (facade) -------------------------------------
    t0 = time.perf_counter()
    fl_curve = _federated_curve(data, rounds, "fedavg")
    wall = time.perf_counter() - t0

    rows = []
    for r in range(rounds):
        rows.append(("fig7_convergence",
                     wall / rounds * 1e6,
                     {"round": r, "fl_acc": round(fl_curve[r], 4),
                      "local_acc": round(local_curve[r], 4)}))
    if verbose:
        for _, _, d in rows:
            print(f"  round {d['round']}: FL {d['fl_acc']:.3f} "
                  f"local {d['local_acc']:.3f}")
    final_gap = abs(fl_curve[-1] - local_curve[-1])
    rows.append(("fig7_final", wall * 1e6,
                 {"fl_final": round(fl_curve[-1], 4),
                  "local_final": round(local_curve[-1], 4),
                  "gap": round(final_gap, 4)}))

    # ---- strategy robustness: one poisoned client ----------------------
    pr = min(rounds, 5)
    finals = {}
    for strat in ("fedavg", "trimmed_mean", "coordinate_median"):
        t0 = time.perf_counter()
        c = _federated_curve(data, pr, strat, poison=True)
        finals[strat] = round(c[-1], 4)
        rows.append(("strategy_under_poison",
                     (time.perf_counter() - t0) * 1e6,
                     {"strategy": strat, "final_acc": finals[strat]}))
    if verbose:
        print(f"  poisoned-client final acc: {finals}")
    return rows


if __name__ == "__main__":
    run()
