"""Paper Fig. 7: MLP accuracy convergence — local training (5% of data)
vs SDFLMQ federated (5 clients x 1% each, FedAvg through the cluster tree
over the sim broker)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.broker import SimBroker
from repro.core.client import SDFLMQClient
from repro.core.coordinator import Coordinator, CoordinatorConfig
from repro.core.parameter_server import ParameterServer
from repro.data.federated import FederatedMNIST
from repro.train.mlp import accuracy, init_mlp, train_epochs

N_CLIENTS = 5
ROUNDS = 10
EPOCHS = 5


def run(rounds: int = ROUNDS, verbose: bool = True):
    data = FederatedMNIST(N_CLIENTS, frac_per_client=0.01, total=20000)
    xt, yt = data.test

    # ---- offline baseline: one node with 5% of the data ----------------
    xs = np.concatenate([data.client_data(i)[0] for i in range(N_CLIENTS)])
    ys = np.concatenate([data.client_data(i)[1] for i in range(N_CLIENTS)])
    local = init_mlp(seed=0)
    local_curve = []
    for r in range(rounds):
        local = train_epochs(local, xs, ys, epochs=EPOCHS, seed=r)
        local_curve.append(accuracy(local, xt, yt))

    # ---- SDFLMQ federated ----------------------------------------------
    broker = SimBroker()
    coord = Coordinator(broker, CoordinatorConfig(levels=3,
                                                  aggregator_ratio=0.4))
    ps = ParameterServer(broker)
    clients = {f"c{i}": SDFLMQClient(f"c{i}", broker) for i in range(N_CLIENTS)}
    clients["c0"].create_fl_session("fig7", "mlp", rounds, N_CLIENTS,
                                    N_CLIENTS)
    for i in range(1, N_CLIENTS):
        clients[f"c{i}"].join_fl_session("fig7", "mlp")

    global_p = init_mlp(seed=0)
    fl_curve = []
    t0 = time.perf_counter()
    for r in range(rounds):
        for i, (cid, cl) in enumerate(sorted(clients.items())):
            x, y = data.client_data(i)
            local_p = train_epochs(global_p, x, y, epochs=EPOCHS, seed=r)
            cl.set_model("fig7", local_p, n_samples=data.n_samples(i))
        for cid, cl in sorted(clients.items()):
            cl.send_local("fig7")
        g = ps.get_global("fig7")["params"]
        global_p = {k: np.asarray(v) for k, v in g.items()}
        fl_curve.append(accuracy(global_p, xt, yt))
        for cid, cl in sorted(clients.items()):
            cl.signal_ready("fig7")
    wall = time.perf_counter() - t0

    rows = []
    for r in range(rounds):
        rows.append(("fig7_convergence",
                     wall / rounds * 1e6,
                     {"round": r, "fl_acc": round(fl_curve[r], 4),
                      "local_acc": round(local_curve[r], 4)}))
    if verbose:
        for _, _, d in rows:
            print(f"  round {d['round']}: FL {d['fl_acc']:.3f} "
                  f"local {d['local_acc']:.3f}")
    final_gap = abs(fl_curve[-1] - local_curve[-1])
    rows.append(("fig7_final", wall * 1e6,
                 {"fl_final": round(fl_curve[-1], 4),
                  "local_final": round(local_curve[-1], 4),
                  "gap": round(final_gap, 4)}))
    return rows


if __name__ == "__main__":
    run()
