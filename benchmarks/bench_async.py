"""Sync vs async federation: virtual wall-clock to a fixed target loss
under a straggler tail.

The fleet has a heavy tail: most clients train+upload in ~1 virtual
second, two stragglers take 6-10x longer.  The synchronous round protocol
gates every round on the slowest client; the async K-of-N path
(repro.api.async_fl) keeps minting globals at the fast clients' cadence
while stragglers fold in late-but-stamped.  Both runs share the same
contractive training dynamics (each client pulls the global toward its own
target; loss = MSE of the global against the all-client target mean) and
the same discrete-event clock, so the comparison is deterministic and
machine-independent — the derived speedup is gated in CI.
"""
from __future__ import annotations

import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import numpy as np

from repro.api import Federation, scenarios

N_FAST = 6
TAIL_S = {"c6": 6.0, "c7": 10.0}        # straggler compute+upload times
FAST_S = 1.0
STEP = 0.5                               # contraction per local update
TARGET_LOSS = 0.05
BUFFER_K = 4


def _fleet_spec():
    n = N_FAST + len(TAIL_S)
    rng = np.random.default_rng(11)
    dim = 64 if os.environ.get("SMOKE") else 4096
    base = rng.normal(loc=3.0, scale=0.25, size=n).astype(np.float32)
    targets = {f"c{i}": np.full(dim, base[i], np.float32) for i in range(n)}
    mean_target = np.mean([targets[c] for c in targets], axis=0)
    return n, dim, targets, mean_target


def _train_fn(targets):
    def train(cid, g, r):
        base = np.zeros_like(targets[cid]) if g is None \
            else np.asarray(g["w"])
        return {"w": (base + np.float32(STEP) * (targets[cid] - base))}, 1
    return train


def _loss(params, mean_target) -> float:
    return float(np.mean((np.asarray(params["w"]) - mean_target) ** 2))


def _time_to_target(curve, target):
    for t, loss in curve:
        if loss <= target:
            return t
    return None


def _run_sync(n, targets, mean_target, rounds=20):
    fed = Federation(latency=dict(seed=5), aggregator_ratio=0.4)
    clients = [fed.client(f"c{i}") for i in range(n)]
    for cid in clients:
        fed.transport.set_link(cid.client_id,
                               delay_s=TAIL_S.get(cid.client_id, FAST_S))
    session = fed.create_session("sync", "m", rounds=rounds,
                                 participants=clients)
    curve = []
    session.on_global_update = lambda p, v: curve.append(
        (fed.clock.now, _loss(p, mean_target)))
    scenarios.play(session, _train_fn(targets), rounds=rounds,
                   round_time_s=1.0,
                   initial_params={"w": np.zeros_like(mean_target)})
    return curve


def _run_async(n, targets, mean_target, versions=60):
    fed = Federation(latency=dict(seed=5), aggregator_ratio=0.4)
    clients = [fed.client(f"c{i}") for i in range(n)]
    periods = {c: TAIL_S.get(c, FAST_S) for c in (cl.client_id
                                                  for cl in clients)}
    session = fed.create_session(
        "async", "m", rounds=versions, participants=clients,
        async_mode=dict(buffer_k=BUFFER_K, staleness_bound=6,
                        staleness_weight="poly", poly_a=0.5,
                        base_period_s=FAST_S, periods=periods, seed=5))
    curve = []
    session.on_global_update = lambda p, v: curve.append(
        (fed.clock.now, _loss(p, mean_target)))
    report = session.run_async(_train_fn(targets), max_time_s=400.0,
                               initial_params={"w":
                                               np.zeros_like(mean_target)})
    return curve, report


def run(verbose: bool = True):
    n, dim, targets, mean_target = _fleet_spec()
    sync_curve = _run_sync(n, targets, mean_target)
    async_curve, report = _run_async(n, targets, mean_target)
    t_sync = _time_to_target(sync_curve, TARGET_LOSS)
    t_async = _time_to_target(async_curve, TARGET_LOSS)
    assert t_sync is not None, "sync run never reached the target loss"
    assert t_async is not None, "async run never reached the target loss"
    speedup = t_sync / t_async
    rows = [
        ("async_sync_time_to_target", t_sync * 1e6,
         {"virtual_s": round(t_sync, 3), "rounds": len(sync_curve),
          "target_loss": TARGET_LOSS, "dim": dim}),
        ("async_async_time_to_target", t_async * 1e6,
         {"virtual_s": round(t_async, 3), "updates": len(async_curve),
          "buffer_k": BUFFER_K, "admitted": report.admitted,
          "rejected_stale": report.rejected_stale}),
        ("async_speedup", (t_sync - t_async) * 1e6,
         {"speedup_x": round(speedup, 2), "target_loss": TARGET_LOSS,
          "straggler_tail_s": sorted(TAIL_S.values())}),
    ]
    if verbose:
        print(f"  sync:  {t_sync:8.2f} virtual s to loss {TARGET_LOSS} "
              f"({len(sync_curve)} rounds, straggler-gated)")
        print(f"  async: {t_async:8.2f} virtual s to loss {TARGET_LOSS} "
              f"({len(async_curve)} updates, K={BUFFER_K})")
        print(f"  async speedup: {speedup:.2f}x")
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(name, round(us, 1), derived)
