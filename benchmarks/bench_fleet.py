"""Fleet-scale simulation: how many logical clients one process can push
through the full SDFLMQ round protocol (join -> arrange -> train -> tree
aggregation -> global broadcast -> readiness) per second.

The sweep runs 1k -> 10k -> 100k logical clients behind ``CohortClient``
endpoints (struct-of-arrays banks, batched control plane, vectorized local
training) and records wall-clock + throughput per size; CI gates a
throughput floor on the JSON artifact.  ``SMOKE=1`` shrinks the sweep.
"""
from __future__ import annotations

import os
import time
import tracemalloc

import numpy as np

from repro.api import Federation, LatencyTransport, SimClock
from repro.core.broker import SimBroker

SMOKE = bool(os.environ.get("SMOKE"))
SWEEP = [200, 1000] if SMOKE else [1000, 10000, 100000]
COHORT_SIZE = 5000
ROUNDS = 3
D = 32          # tiny model: the bench measures protocol, not matmul


def _drift(n: int) -> np.ndarray:
    return (np.arange(n, dtype=np.float64) % 101) / 101.0


def _run_fleet(n_clients: int, rounds: int = ROUNDS,
               trace_mem: bool = False):
    """One federation, ``n_clients`` logical ids in ceil(n/5000) cohorts,
    ``rounds`` full rounds on the vectorized bank path."""
    if trace_mem:
        tracemalloc.start()
    t0 = time.perf_counter()
    fed = Federation()
    ids = [f"c{i:06d}" for i in range(n_clients)]
    cohorts = [fed.cohort(f"co{k:03d}", ids[k:k + COHORT_SIZE])
               for k in range(0, n_clients, COHORT_SIZE)]
    init = {"w": np.zeros(D, np.float32)}
    session = fed.create_fleet_session("fleet", "m", rounds=rounds,
                                       cohorts=cohorts, initial_params=init)
    setup_s = time.perf_counter() - t0
    assert session.state == "running", session.state

    def vtrain(data, weights, global_params):
        for arr in data.values():
            d = _drift(arr.shape[0]).reshape((-1,) + (1,) * (arr.ndim - 1))
            np.multiply(arr, 0.9, out=arr)
            arr += d
        return data, weights

    t1 = time.perf_counter()
    versions = []
    for r in range(rounds):
        session.run_round_vectorized(vtrain)
        fed.deliver()
        versions.append(session.global_version())
    round_s = time.perf_counter() - t1
    assert versions == list(range(1, rounds + 1)), versions
    peak_kb_per_1k = None
    if trace_mem:
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        peak_kb_per_1k = round(peak / 1024 / (n_clients / 1000), 1)
    bypassed = sum(co.bypassed_messages for co in cohorts)
    uplinks = sum(co.uplink_partials for co in cohorts)
    return {
        "clients": n_clients, "cohorts": len(cohorts), "rounds": rounds,
        "setup_s": round(setup_s, 2), "round_wall_s": round(round_s, 2),
        "clients_per_s": round(n_clients * rounds / round_s),
        "bypassed_msgs": bypassed, "uplink_partials": uplinks,
        "broker_msgs": fed.transport.inner.sys_stats()["messages_received"],
        "peak_kb_per_1k_clients": peak_kb_per_1k,
    }


def bench_fleet_sweep():
    rows = []
    for n in SWEEP:
        d = _run_fleet(n, trace_mem=(n <= 10000))
        rows.append((f"fleet_round_{n}", d["round_wall_s"] / ROUNDS * 1e6, d))
    return rows


def bench_timer_drain(n_timers: int = 10000, n_msgs: int = 5000):
    """Satellite: message-only drains must not pay for armed timers.  The
    old single-heap clock popped and re-pushed every earlier timer per
    delivery (O(timers log n) each); the split heaps keep the per-message
    cost flat whether 0 or 10k timers are pending."""
    def drain_cost(timers: int) -> float:
        clock = SimClock()
        for i in range(timers):
            clock.schedule_periodic(10_000.0 + i, lambda: True)
        b = LatencyTransport(SimBroker(), delay_s=0.001, clock=clock)
        sink = [0]
        b.connect("c", lambda m: sink.__setitem__(0, sink[0] + 1))
        b.subscribe("c", "t/#")
        with clock.hold():
            for i in range(n_msgs):
                b.publish("t/a", b"x" * 64, sender=f"s{i % 16}")
            t0 = time.perf_counter()
            clock.run_until_idle()
            dt = time.perf_counter() - t0
        assert sink[0] == n_msgs
        return dt / n_msgs * 1e6

    cold = drain_cost(0)
    hot = drain_cost(n_timers)
    return ("clock_timer_drain", hot,
            {"pending_timers": n_timers, "msgs": n_msgs,
             "us_no_timers": round(cold, 2), "us_10k_timers": round(hot, 2),
             "ratio": round(hot / max(cold, 1e-9), 2)})


def run(verbose: bool = True):
    rows = bench_fleet_sweep() + [bench_timer_drain()]
    if verbose:
        for name, us, d in rows:
            print(f"  {name}: {d}")
    return rows


if __name__ == "__main__":
    run()
